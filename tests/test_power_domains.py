"""Multi-channel power-domain metering tests: MeterStack semantics,
per-channel ranging, PSU-linked cross-domain invariants, fleet PDU
aggregation, the deprecated scalar power_source shim, and the guard
that no in-repo caller outside tests/ still uses the scalar surface."""
import glob
import os
import types
import warnings

import numpy as np
import pytest

from repro.core.compliance import SystemDescription, review
from repro.core.loadgen import Clock, qid_of
from repro.core.power_model import StepWork, SystemPowerModel
from repro.harness import (BaseSUT, CallableSUT, PowerRun, ReplicatedSUT,
                           Server, SingleStream, rail_domains,
                           throughput_work)
from repro.hw import DATACENTER_V5E, EDGE_SYSTEM
from repro.power import (GOLD_CURVE, Meter, MeterStack, PowerDomain,
                         PSUModel, build_stack, single_source_stack,
                         wall_domain)

EDGE_DESC = SystemDescription(scale="edge", max_system_watts=60,
                              idle_system_watts=8)


def _const(w):
    return lambda t: np.full_like(np.asarray(t, float), float(w))


def _rail_stack(acc=10.0, dram=4.0, host=6.0, eta=0.9, sample_hz=50.0,
                seed=0, curve=()):
    psu = PSUModel(rated_watts=40.0, efficiency=eta, curve=curve)
    rails = [PowerDomain("accelerator", _const(acc)),
             PowerDomain("dram", _const(dram)),
             PowerDomain("host", _const(host))]
    wall = PowerDomain("wall", psu.wall_source([r.source for r in rails]),
                       boundary=True)
    return build_stack(rails + [wall], EDGE_DESC, seed=seed,
                       sample_hz=sample_hz, psu=psu), psu


class TestPowerModelRails:
    def test_rails_sum_to_system_watts(self):
        m = SystemPowerModel(DATACENTER_V5E, 8)
        for work in (None, StepWork(flops=1e15, hbm_bytes=1e12,
                                    ici_bytes=1e11)):
            rails = m.rail_watts(work)
            assert set(rails) == {"accelerator", "dram", "host"}
            np.testing.assert_allclose(
                sum(rails.values()) / DATACENTER_V5E.psu_efficiency,
                m.system_watts(work))

    def test_psu_flat_matches_legacy_efficiency(self):
        m = SystemPowerModel(EDGE_SYSTEM, 1)
        psu = m.psu()
        np.testing.assert_allclose(psu.eta(12.3),
                                   EDGE_SYSTEM.psu_efficiency)
        np.testing.assert_allclose(psu.wall_watts(9.4),
                                   9.4 / EDGE_SYSTEM.psu_efficiency)

    def test_psu_curve_sags_at_the_extremes(self):
        psu = PSUModel(rated_watts=100.0, curve=GOLD_CURVE)
        assert psu.eta(5.0) < psu.eta(50.0)
        assert psu.eta(100.0) < psu.eta(50.0)
        assert np.all(psu.wall_watts(np.asarray([5.0, 50.0]))
                      > np.asarray([5.0, 50.0]))


class TestMeterStack:
    def test_per_channel_ranging_golden(self):
        """Two-pass mode pins each channel's own range, not the stack
        peak: a 140 W accelerator next to a 4 W DRAM rail must leave
        the DRAM channel on the 15 W range."""
        stack, _ = _rail_stack(acc=140.0, dram=4.0, host=40.0)
        ranges = stack.range_probe(2.0)
        assert ranges["accelerator"] == 300.0
        assert ranges["dram"] == 15.0
        assert ranges["host"] == 75.0
        # wall = (140+4+40)/0.9 = 204.4 -> its own 300 W range
        assert ranges["wall"] == 300.0
        for m in stack:
            if m.analyzer is not None:
                assert m.analyzer.fixed_range == ranges[m.name]

    def test_shared_timeline_and_boundary_metadata(self):
        from repro.core.mlperf_log import MLPerfLogger

        stack, _ = _rail_stack()
        log = MLPerfLogger("power")
        out = stack.measure(10.0, logger=log)
        grids = {tuple(t) for t, _ in out.values()}
        assert len(grids) == 1              # one shared timeline
        bnd = {(ev.metadata["node"], ev.metadata["boundary"])
               for ev in log.events}
        assert ("wall", True) in bnd
        assert ("accelerator", False) in bnd

    def test_mismatched_rates_rejected(self):
        from repro.core.analyzer import AnalyzerSpec, VirtualAnalyzer

        meters = [
            Meter(PowerDomain("accelerator", _const(5.0)),
                  VirtualAnalyzer(AnalyzerSpec(sample_hz=10.0))),
            Meter(wall_domain(_const(9.0)),
                  VirtualAnalyzer(AnalyzerSpec(sample_hz=20.0))),
        ]
        with pytest.raises(ValueError, match="one timeline"):
            MeterStack(meters).measure(5.0)

    def test_derived_channel_is_exact_sum(self):
        feeds = [PowerDomain(f"r{i}/wall", _const(10.0 + i), kind="wall",
                             group=f"r{i}") for i in range(3)]
        pdu = PowerDomain("pdu", derived_from=tuple(f.name for f in feeds),
                          boundary=True)
        stack = build_stack(feeds + [pdu], EDGE_DESC, sample_hz=20.0)
        out = stack.measure(5.0)
        total = sum(out[f.name][1] for f in feeds)
        np.testing.assert_allclose(out["pdu"][1], total)

    def test_unknown_derived_source_rejected(self):
        with pytest.raises(ValueError, match="unknown"):
            MeterStack([Meter(PowerDomain(
                "pdu", derived_from=("ghost",)))])


class TestCrossDomainInvariants:
    def _perf(self, duration_s=65.0):
        from repro.core.mlperf_log import MLPerfLogger

        log = MLPerfLogger("perf")
        log.run_start(0.0)
        log.result("samples_processed", 100, duration_s * 1e3)
        log.run_stop(duration_s * 1e3)
        return log

    def _measure(self, stack, duration_s=65.0):
        from repro.core.mlperf_log import MLPerfLogger

        power = MLPerfLogger("power")
        stack.measure(duration_s, logger=power)
        return power

    @pytest.mark.parametrize("curve", [(), GOLD_CURVE])
    def test_consistent_stack_accepted(self, curve):
        stack, _ = _rail_stack(curve=curve)
        rep = review(self._perf().events, self._measure(stack).events,
                     EDGE_DESC, meter_stack=stack)
        assert rep.passed, rep.render()
        rules = [c.rule for c in rep.checks]
        assert any(r.startswith("R9") for r in rules)
        assert any(r.startswith("R10") for r in rules)

    def test_underreported_wall_rejected(self):
        """A wall meter reading half the true wall must fail both the
        wall>=rails bound and the PSU consistency check."""
        stack, psu = _rail_stack()
        wall = stack.channel("wall")
        true_src = wall.domain.source
        wall.domain.source = lambda t: 0.5 * np.asarray(true_src(t))
        rep = review(self._perf().events, self._measure(stack).events,
                     EDGE_DESC, meter_stack=stack)
        fails = [c.rule for c in rep.failures()]
        assert any(r.startswith("R9") for r in fails), rep.render()
        assert any(r.startswith("R10") for r in fails)

    def test_wrong_eta_rejected_by_consistency_only(self):
        """Wall inflated by an undocumented extra 20% loss: still >=
        rails (R9 passes) but inconsistent with the declared PSU."""
        stack, psu = _rail_stack()
        wall = stack.channel("wall")
        true_src = wall.domain.source
        wall.domain.source = lambda t: 1.2 * np.asarray(true_src(t))
        rep = review(self._perf().events, self._measure(stack).events,
                     EDGE_DESC, meter_stack=stack)
        fails = [c.rule for c in rep.failures()]
        assert not any(r.startswith("R9") for r in fails), rep.render()
        assert any(r.startswith("R10") for r in fails)

    def test_tampered_pdu_rejected(self):
        """A PDU that claims both feeds but whose register under-
        reports their sum must fail the aggregation invariant."""
        feeds = [PowerDomain(f"r{i}/wall", _const(10.0), kind="wall",
                             group=f"r{i}") for i in range(2)]
        pdu = PowerDomain("pdu",
                          derived_from=tuple(f.name for f in feeds),
                          combine=lambda ws: 0.5 * np.sum(ws, axis=0),
                          boundary=True)
        stack = build_stack(feeds + [pdu], EDGE_DESC, sample_hz=20.0)
        rep = review(self._perf().events, self._measure(stack).events,
                     EDGE_DESC, meter_stack=stack)
        assert any(c.rule.startswith("R11") for c in rep.failures()), \
            rep.render()

    def test_pdu_with_extra_standalone_wall_not_rejected(self):
        """R11 scopes to the PDU's own members: an additional wall
        monitor outside the PDU must not fail the aggregation check."""
        feeds = [PowerDomain(f"r{i}/wall", _const(10.0), kind="wall",
                             group=f"r{i}") for i in range(2)]
        extra = PowerDomain("monitor/wall", _const(99.0), kind="wall",
                            group="monitor")
        pdu = PowerDomain("pdu",
                          derived_from=tuple(f.name for f in feeds),
                          boundary=True)
        stack = build_stack(feeds + [extra, pdu], EDGE_DESC,
                            sample_hz=20.0)
        rep = review(self._perf().events, self._measure(stack).events,
                     EDGE_DESC, meter_stack=stack)
        assert not any(c.rule.startswith("R11")
                       for c in rep.failures()), rep.render()


class TestEnergyConservationProperty:
    """Sigma per-domain energy (+ PSU loss) == wall energy within the
    channels' error model, across random workload shapes.

    A seeded randomized sweep (not hypothesis) so the property runs on
    minimal containers too — the draw space mirrors what a strategy
    would generate: rail levels across two decades, flat-vs-duty-cycled
    shapes, the full realistic PSU efficiency band."""

    @pytest.mark.parametrize("case", range(25))
    def test_property_random_stacks(self, case):
        rng = np.random.default_rng(1234 + case)
        acc = float(rng.uniform(5.0, 200.0))
        dram = float(rng.uniform(1.0, 60.0))
        host = float(rng.uniform(1.0, 80.0))
        eta = float(rng.uniform(0.75, 0.98))
        duty = float(rng.uniform(0.1, 1.0))
        seed = int(rng.integers(0, 1000))
        psu = PSUModel(rated_watts=400.0, efficiency=eta)

        def shaped(w):
            return lambda t: w * (0.3 + 0.7 * (
                (np.asarray(t, float) % 1.0) < duty))

        rails = [PowerDomain("accelerator", shaped(acc)),
                 PowerDomain("dram", shaped(dram)),
                 PowerDomain("host", shaped(host))]
        wall = PowerDomain(
            "wall", psu.wall_source([r.source for r in rails]),
            boundary=True)
        stack = build_stack(rails + [wall], EDGE_DESC, seed=seed,
                            sample_hz=40.0, psu=psu)
        stack.range_probe(2.0)
        out = stack.measure(30.0)
        t_s = out["wall"][0] / 1e3
        e = {name: (np.trapezoid(w, t_s) if hasattr(np, "trapezoid")
                    else np.trapz(w, t_s))
             for name, (_, w) in out.items()}
        rails_j = e["accelerator"] + e["dram"] + e["host"]
        loss_j = rails_j * (1.0 / eta - 1.0)
        # error model bound: 0.1% gain per channel (fixed range)
        # + offset noise; 2% relative slack covers the offsets
        assert e["wall"] == pytest.approx(rails_j + loss_j, rel=0.02)


class TestSUTAdapters:
    def test_rail_domains_split_accelerator_channels(self):
        m = SystemPowerModel(DATACENTER_V5E, 4)
        work = StepWork(flops=1e15, hbm_bytes=1e12)
        doms = rail_domains(m, work, n_accel_channels=4)
        names = [d.name for d in doms]
        assert names == ["accelerator/0", "accelerator/1",
                         "accelerator/2", "accelerator/3", "dram",
                         "host", "wall"]
        t = np.asarray([0.0, 1.0])
        acc = sum(d.source(t) for d in doms if d.kind == "accelerator")
        single = rail_domains(m, work)[0].source(t)
        np.testing.assert_allclose(acc, single)
        # the wall is the boundary; the shards are breakdown channels
        assert [d.boundary for d in doms] == [False] * 6 + [True]

    def test_serve_engine_sut_domains(self):
        from repro.harness import ServeEngineSUT

        class Cfg:
            def param_count(self):
                return 50_000_000

        sut = ServeEngineSUT(None, Cfg(), make_requests=lambda s: s,
                             sysdesc=EDGE_DESC)
        out = types.SimpleNamespace(result=types.SimpleNamespace(qps=8.0))
        doms = sut.domains(out)
        assert [d.name for d in doms] == ["accelerator", "dram", "host",
                                          "wall"]
        assert doms[-1].boundary and not doms[0].boundary
        t = np.asarray([0.0, 1.0])
        rails = sum(d.source(t) for d in doms[:-1])
        np.testing.assert_allclose(
            doms[-1].source(t),
            rails / EDGE_SYSTEM.psu_efficiency)
        # ... and matches the legacy scalar wall figure exactly
        np.testing.assert_allclose(doms[-1].source(t),
                                   sut.power_source(out)(t))

    def test_powerrun_reports_per_domain_energy(self):
        m = SystemPowerModel(EDGE_SYSTEM, 1)

        class Cfg:
            def param_count(self):
                return 50_000_000

        sut = CallableSUT(
            issue=lambda s: 0.05, psu=m.psu(),
            domains_factory=lambda o: rail_domains(
                m, throughput_work(Cfg(), o.result.qps)),
            sysdesc=EDGE_DESC)
        r = PowerRun(sut, SingleStream(min_duration_s=61.0),
                     clock=Clock(), seed=0).run()
        assert r.passed, r.report.render()
        e = r.per_domain_energy_j
        assert set(e) == {"accelerator", "dram", "host", "wall"}
        assert r.summary.boundary_nodes == ("wall",)
        # total energy is the wall, not wall + rails double-counted
        np.testing.assert_allclose(r.summary.energy_j, e["wall"])
        rails = e["accelerator"] + e["dram"] + e["host"]
        assert e["wall"] == pytest.approx(
            rails / EDGE_SYSTEM.psu_efficiency, rel=0.02)
        assert set(r.submission.domain_samples_per_joule()) == set(e)

    def test_per_request_energy_attributed_per_domain(self):
        class QueueSUT(BaseSUT):
            def __init__(self):
                super().__init__("dom-queue", EDGE_DESC)
                self.completed = []

            def serve_queue(self, arrivals):
                self.completed = [types.SimpleNamespace(
                    rid=i, arrival_s=a, first_token_s=a + 0.01,
                    done_s=a + 1.0, output=[0], energy_j=None)
                    for i, (_, a) in enumerate(arrivals)]
                return self.completed

            def supports_serve_queue(self):
                return True

            def completed_requests(self):
                return self.completed or None

            def domains(self, outcome):
                psu = PSUModel(rated_watts=60.0, efficiency=0.9)
                rails = [PowerDomain("accelerator", _const(9.0)),
                         PowerDomain("host", _const(9.0))]
                return rails + [PowerDomain(
                    "wall", psu.wall_source([r.source for r in rails]),
                    boundary=True)]

        sut = QueueSUT()
        r = PowerRun(sut, Server(target_qps=2.0, min_duration_s=61.0,
                                 latency_slo_s=2.0), seed=0).run()
        assert r.per_request_energy_j is not None
        assert set(r.per_request_domain_energy_j) == \
            {"accelerator", "host", "wall"}
        for per in r.per_request_domain_energy_j.values():
            assert set(per) == set(r.per_request_energy_j)
        wall_sum = sum(r.per_request_domain_energy_j["wall"].values())
        np.testing.assert_allclose(
            wall_sum, sum(r.per_request_energy_j.values()))
        # records keep the boundary (submission-total) view
        total = sum(r.per_request_energy_j.values())
        assert sum(req.energy_j for req in sut.completed) == \
            pytest.approx(total)


class TestReplicatedPDU:
    def _fleet(self, n=2):
        def make_replica(i):
            def serve(arrivals):
                return [types.SimpleNamespace(
                    rid=qid_of(s, j), arrival_s=a,
                    first_token_s=a + 0.01, done_s=a + 0.05,
                    output=[1, 2], energy_j=None)
                    for j, (s, a) in enumerate(arrivals)]

            psu = PSUModel(rated_watts=60.0, efficiency=0.9)
            rails = [PowerDomain("accelerator", _const(8.0 + i)),
                     PowerDomain("host", _const(5.0))]
            wall = PowerDomain(
                "wall", psu.wall_source([r.source for r in rails]),
                boundary=True)
            return CallableSUT(
                name=f"rep{i}", serve_queue=serve, psu=psu,
                domains_factory=lambda o: rails + [wall],
                sysdesc=EDGE_DESC)

        return ReplicatedSUT([make_replica(i) for i in range(n)],
                             name="fleet")

    def test_pdu_energy_equals_sum_of_replica_walls(self):
        sut = self._fleet()
        r = PowerRun(sut, Server(target_qps=4.0, latency_slo_s=1.0,
                                 mode="queue", min_duration_s=61.0),
                     seed=0).run()
        assert r.passed, r.report.render()
        e = r.per_domain_energy_j
        assert r.summary.boundary_nodes == ("pdu",)
        walls = [e["r0/wall"], e["r1/wall"]]
        # the PDU register is the exact sum of its measured feeds
        np.testing.assert_allclose(e["pdu"], sum(walls))
        np.testing.assert_allclose(r.summary.energy_j, e["pdu"])
        # per-replica rails made it through with the r{i}/ prefix
        assert "r0/accelerator" in e and "r1/host" in e


class TestScalarShimAndGuards:
    def test_power_source_shim_warns_and_measures(self):
        class Legacy(BaseSUT):
            def __init__(self):
                super().__init__("legacy", EDGE_DESC)

            def issue(self, s):
                return 0.05

            def power_source(self, outcome):
                return _const(21.0)

        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            r = PowerRun(Legacy(), SingleStream(min_duration_s=61.0),
                         clock=Clock(), seed=0).run()
        assert any(issubclass(w.category, DeprecationWarning)
                   for w in caught)
        assert r.passed
        assert set(r.per_domain_energy_j) == {"wall"}

    def test_callable_power_source_kwarg_warns(self):
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            sut = CallableSUT(issue=lambda s: 0.01,
                              power_source=_const(5.0),
                              sysdesc=EDGE_DESC)
        assert any(issubclass(w.category, DeprecationWarning)
                   for w in caught)
        doms = sut.domains(None)
        assert [d.name for d in doms] == ["wall"]

    def test_no_in_repo_caller_uses_scalar_power_source(self):
        """Acceptance guard: outside tests/ (and the shim definitions
        in the harness itself), no benchmark, example, or launcher
        still drives the deprecated scalar surface."""
        root = os.path.join(os.path.dirname(__file__), "..")
        offenders = []
        for d in ("benchmarks", "examples",
                  os.path.join("src", "repro", "launch"),
                  os.path.join("src", "repro", "serving")):
            for p in glob.glob(os.path.join(root, d, "**", "*.py"),
                               recursive=True):
                with open(p) as f:
                    text = f.read()
                if "power_source" in text:
                    offenders.append(os.path.relpath(p, root))
        assert not offenders, offenders

    def test_analyzer_spec_default_not_shared(self):
        """The shared-mutable-default bug: two analyzers built without
        an explicit spec must not share one AnalyzerSpec instance."""
        from repro.core.analyzer import NodeTelemetry, VirtualAnalyzer

        a, b = VirtualAnalyzer(), VirtualAnalyzer()
        assert a.spec is not b.spec
        a.spec.sample_hz = 123.0
        assert b.spec.sample_hz != 123.0
        t, u = NodeTelemetry(), NodeTelemetry()
        assert t.spec is not u.spec

    def test_single_source_stack_matches_legacy_director(self):
        """The wrapped scalar path is draw-for-draw identical to the
        pre-domain single-analyzer measurement."""
        from repro.core.analyzer import VirtualAnalyzer

        src = _const(42.0)
        legacy = VirtualAnalyzer(seed=7)
        legacy.range_probe(src, 2.0)
        t_old, w_old = legacy.measure(src, 30.0)

        stack = single_source_stack(src, VirtualAnalyzer(seed=7))
        stack.range_probe(2.0)
        (t_new, w_new), = stack.measure(30.0).values()
        np.testing.assert_array_equal(t_old, t_new)
        np.testing.assert_array_equal(w_old, w_new)
