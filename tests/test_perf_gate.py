"""CI perf-regression gate logic: an injected >=20% tok/s regression
must fail the build, machine-speed drift must not."""
import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "scripts"))
from perf_gate import compare, flatten, main  # noqa: E402

BASE = {
    "serving": {
        "fixed": {"tokens_per_s": 1000.0, "tok_per_j": 50.0,
                  "us_per_tok": 1000.0},
        "continuous": {"tokens_per_s": 1500.0, "tok_per_j": 75.0,
                       "us_per_tok": 660.0},
        "speedup": 1.5,
        "qps": 200.0,
        "chunk_syncs": 25,
    },
    "scale": {
        "tp1": {"tokens_per_s": 1700.0, "tok_per_j": 100.0, "chips": 1},
        "tp4": {"tokens_per_s": 90.0, "tok_per_j": 1.2, "chips": 4},
    },
}


def test_identical_metrics_pass():
    failures, _ = compare(copy.deepcopy(BASE), BASE)
    assert failures == []


def test_injected_20pct_tok_s_regression_fails_the_gate():
    cur = copy.deepcopy(BASE)
    cur["serving"]["continuous"]["tokens_per_s"] *= 0.80
    cur["serving"]["speedup"] *= 0.80
    failures, _ = compare(cur, BASE)
    assert any("serving.continuous.tokens_per_s" in f for f in failures)
    assert any("serving.speedup" in f for f in failures)


def test_tok_per_j_regression_fails_too():
    cur = copy.deepcopy(BASE)
    cur["serving"]["continuous"]["tok_per_j"] *= 0.7
    failures, _ = compare(cur, BASE)
    assert any("serving.continuous.tok_per_j" in f for f in failures)


def test_scale_group_has_a_wider_noise_floor():
    """Virtual-mesh scale points gate at a documented wider tolerance:
    a 20% dip there is within measured noise, a 40% collapse is not."""
    cur = copy.deepcopy(BASE)
    cur["scale"]["tp4"]["tok_per_j"] *= 0.8
    failures, _ = compare(cur, BASE)
    assert failures == []
    cur["scale"]["tp4"]["tok_per_j"] = BASE["scale"]["tp4"]["tok_per_j"] * 0.6
    failures, _ = compare(cur, BASE)
    assert any("scale.tp4.tok_per_j" in f for f in failures)


def test_small_drift_within_tolerance_passes():
    cur = copy.deepcopy(BASE)
    for point in ("fixed", "continuous"):
        cur["serving"][point]["tokens_per_s"] *= 0.95
    failures, _ = compare(cur, BASE)
    assert failures == []


def test_uniform_machine_slowdown_is_normalized_away():
    """A 2x slower CI machine halves every rate including the
    calibration workload — not a regression."""
    cur = copy.deepcopy(BASE)
    for grp in cur.values():
        for point in grp.values():
            if isinstance(point, dict):
                for key in ("tokens_per_s", "tok_per_j"):
                    if key in point:
                        point[key] *= 0.5
    failures, notes = compare(cur, BASE)
    assert failures == []
    assert any("0.50x the baseline machine" in n for n in notes)


def test_relative_regression_survives_normalization():
    """Same slow machine, but the continuous engine regressed 25% on
    top of it: normalization must still expose it."""
    cur = copy.deepcopy(BASE)
    for grp in cur.values():
        for point in grp.values():
            if isinstance(point, dict):
                for key in ("tokens_per_s", "tok_per_j"):
                    if key in point:
                        point[key] *= 0.5
    cur["serving"]["continuous"]["tokens_per_s"] *= 0.75
    failures, _ = compare(cur, BASE)
    assert any("serving.continuous.tokens_per_s" in f for f in failures)


def test_calibration_workload_regression_hits_its_raw_floor():
    """A *collapse* confined to the calibration metric cannot hide
    behind normalization: it fails its own raw floor.  The floor is
    deliberately very loose — a slower CI runner (raw wall-clock is
    machine-specific) stays a note, not a failure."""
    cur = copy.deepcopy(BASE)
    cur["serving"]["fixed"]["tokens_per_s"] *= 0.2   # 5x collapse
    failures, _ = compare(cur, BASE)
    assert any("serving.fixed.tokens_per_s" in f and "raw floor" in f
               for f in failures)
    # a plausible machine-speed difference stays a note, not a failure
    cur2 = copy.deepcopy(BASE)
    cur2["serving"]["fixed"]["tokens_per_s"] *= 0.45
    failures2, _ = compare(cur2, BASE)
    assert not any("raw floor" in f for f in failures2)


def test_meter_overhead_has_its_own_raw_floor():
    """The pure-numpy metering throughput is NOT normalized by the
    JAX-bound serving calibration: a faster-JAX machine must not fail
    a healthy meter, while a de-vectorization-scale collapse must."""
    base = copy.deepcopy(BASE)
    base["serving"]["meter_samples_per_s"] = 20e6
    # 1.3x-JAX machine, numpy unchanged: would fail if cross-normalized
    cur = copy.deepcopy(base)
    for point in ("fixed", "continuous"):
        for key in ("tokens_per_s", "tok_per_j"):
            cur["serving"][point][key] *= 1.3
    failures, _ = compare(cur, base)
    assert not any("meter_samples_per_s" in f for f in failures)
    # a 20x collapse (de-vectorized sampling loop) trips the floor
    cur2 = copy.deepcopy(base)
    cur2["serving"]["meter_samples_per_s"] = 1e6
    failures2, _ = compare(cur2, base)
    assert any("meter_samples_per_s" in f for f in failures2)


def test_speedup_ratio_is_not_rescaled_by_machine_speed():
    """Ratios are machine-independent; only their own drop may fail."""
    cur = copy.deepcopy(BASE)
    cur["serving"]["fixed"]["tokens_per_s"] *= 2.0   # calibration 2x
    failures, _ = compare(cur, BASE)
    assert not any("speedup" in f for f in failures)


def test_missing_and_new_metrics_are_notes_not_failures():
    cur = copy.deepcopy(BASE)
    del cur["scale"]["tp4"]                    # e.g. no virtual devices
    cur["scale"]["r2"] = {"tokens_per_s": 1400.0, "tok_per_j": 50.0}
    failures, notes = compare(cur, BASE)
    assert failures == []
    assert any("missing in current run: scale.tp4" in n for n in notes)
    assert any("not in baseline yet: scale.r2" in n for n in notes)
    assert any("refresh" in n for n in notes)


def test_flatten_addresses_leaves_with_dotted_paths():
    flat = flatten(BASE)
    assert flat["serving.continuous.tokens_per_s"] == 1500.0
    assert flat["scale.tp4.chips"] == 4.0


def test_cli_fails_build_on_regression(tmp_path, monkeypatch):
    """The CLI path: exit 1 on an injected regression, 0 when clean —
    with the benchmark collection stubbed out."""
    import perf_gate

    baseline = tmp_path / "smoke.json"
    baseline.write_text(json.dumps(BASE))
    cur = copy.deepcopy(BASE)
    cur["serving"]["continuous"]["tokens_per_s"] *= 0.80
    monkeypatch.setattr(perf_gate, "collect", lambda smoke=True: cur)
    assert main(["--smoke", "--baseline", str(baseline)]) == 1
    monkeypatch.setattr(perf_gate, "collect",
                        lambda smoke=True: copy.deepcopy(BASE))
    assert main(["--smoke", "--baseline", str(baseline)]) == 0


def test_cli_missing_baseline_prints_refresh_and_fails(tmp_path,
                                                       monkeypatch,
                                                       capsys):
    import perf_gate

    monkeypatch.setattr(perf_gate, "collect",
                        lambda smoke=True: copy.deepcopy(BASE))
    rc = main(["--smoke", "--baseline", str(tmp_path / "nope.json")])
    out = capsys.readouterr().out
    assert rc == 1
    assert "--update-baseline" in out


def test_cli_update_baseline_writes_file(tmp_path, monkeypatch):
    import perf_gate

    monkeypatch.setattr(perf_gate, "collect",
                        lambda smoke=True: copy.deepcopy(BASE))
    baseline = tmp_path / "smoke.json"
    assert main(["--smoke", "--baseline", str(baseline),
                 "--update-baseline"]) == 0
    assert json.loads(baseline.read_text()) == BASE
    # and the freshly written baseline gates clean
    assert main(["--smoke", "--baseline", str(baseline)]) == 0


def test_committed_baseline_is_valid_json_with_gated_metrics():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "baselines", "smoke.json")
    with open(path) as f:
        base = json.load(f)
    flat = flatten(base)
    assert "serving.fixed.tokens_per_s" in flat      # calibration key
    assert "serving.continuous.tok_per_j" in flat
    assert all(v > 0 for k, v in flat.items()
               if k.endswith(("tokens_per_s", "tok_per_j")))


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
