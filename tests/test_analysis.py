"""Static-analysis suite tests: golden fixtures per rule family plus
the meta-test that the live repo tree is clean.

Each rule is proven on a minimal fixture that a human can eyeball —
the KRN fixtures are in-code contracts (gap / double-write / bad
block), the PUR/UNT fixtures are small Python files written to a tmp
tree — and asserted down to rule id, file:line, and fix-hint substance.
The baseline ratchet is tested in both directions: a new finding fails
the gate, and a baselined finding that vanishes also fails the gate.
"""
import json
import os
import subprocess
import sys
import textwrap

from repro.analysis import kernels as krn
from repro.analysis import purity as pur
from repro.analysis import units as unt
from repro.analysis.contracts import (KernelContract, KernelInstance,
                                      OperandSpec, ScratchSpec)
from repro.analysis.findings import (Finding, file_suppressions, gate,
                                     is_suppressed, load_baseline,
                                     save_baseline, UNREVIEWED)
from repro.analysis.runner import run_all

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rules(findings):
    return sorted(f.rule for f in findings)


def _contract(build, **kw):
    kw.setdefault("cases", ({},))
    return KernelContract(name="fixture", build=build, **kw)


def _write_tree(tmp_path, name, body):
    p = tmp_path / name
    p.write_text(textwrap.dedent(body))
    return str(p)


# --- Pass 1: kernel contracts (KRN) --------------------------------------

class TestKernelRules:
    def test_gap_krn001(self):
        # grid of 2 over 4 output row-blocks: half never written
        def build(case):
            return KernelInstance(
                grid=(2,), semantics=("parallel",), inputs=(),
                outputs=(OperandSpec(
                    "o", (4, 8), "float32", block=(1, 8),
                    index_map=lambda i: (i, 0)),))
        out = krn.check_contract(_contract(build), ROOT)
        assert _rules(out) == ["KRN001"]
        f = out[0]
        assert "2 of 4 blocks never written" in f.message
        assert "(2, 0)" in f.message            # the first gap, named
        assert f.path.endswith("tests/test_analysis.py")
        assert f.line > 0 and "tile the whole output" in f.hint

    def test_parallel_double_write_krn002(self):
        # both parallel dims map to the same output row: a race
        def build(case):
            return KernelInstance(
                grid=(2, 2), semantics=("parallel", "parallel"),
                inputs=(),
                outputs=(OperandSpec(
                    "o", (2, 8), "float32", block=(1, 8),
                    index_map=lambda i, j: (i, 0)),))
        out = krn.check_contract(_contract(build), ROOT)
        assert _rules(out) == ["KRN002"]
        assert "2 distinct parallel grid points" in out[0].message

    def test_arbitrary_revisit_is_legal(self):
        # same shape as the KRN002 case, but the second dim is the
        # accumulation dim: no finding
        def build(case):
            return KernelInstance(
                grid=(2, 2), semantics=("parallel", "arbitrary"),
                inputs=(),
                outputs=(OperandSpec(
                    "o", (2, 8), "float32", block=(1, 8),
                    index_map=lambda i, k: (i, 0)),))
        assert krn.check_contract(_contract(build), ROOT) == []

    def test_block_divisibility_krn003(self):
        def build(case):
            return KernelInstance(
                grid=(1,), semantics=("parallel",),
                inputs=(OperandSpec(
                    "x", (100, 8), "float32", block=(48, 8),
                    index_map=lambda i: (i, 0)),),
                outputs=(OperandSpec(
                    "o", (100, 8), "float32", block=(100, 8),
                    index_map=lambda i: (0, 0)),))
        out = krn.check_contract(_contract(build), ROOT)
        assert _rules(out) == ["KRN003"]
        assert "block 48 does not divide shape 100" in out[0].message
        assert "fit_block_k" in out[0].hint

    def test_dtype_group_krn004(self):
        def build(case):
            return KernelInstance(
                grid=(1,), semantics=("parallel",),
                inputs=(OperandSpec("x", (8, 8), "int8"),
                        OperandSpec("w", (8, 8), "bfloat16")),
                outputs=(OperandSpec(
                    "o", (8, 8), "float32", block=(8, 8),
                    index_map=lambda i: (0, 0)),))
        out = krn.check_contract(
            _contract(build, dtype_groups=(("x", "w"),)), ROOT)
        assert _rules(out) == ["KRN004"]

    def test_vmem_budget_krn005(self):
        # one 32 MiB streamed block, double-buffered: over any budget
        def build(case):
            return KernelInstance(
                grid=(1,), semantics=("parallel",),
                inputs=(OperandSpec(
                    "x", (4096, 2048), "float32", block=(4096, 2048),
                    index_map=lambda i: (0, 0)),),
                outputs=(OperandSpec(
                    "o", (8, 128), "float32", block=(8, 128),
                    index_map=lambda i: (0, 0)),),
                scratch=(ScratchSpec((8, 128), "float32"),))
        out = krn.check_contract(_contract(build), ROOT)
        assert _rules(out) == ["KRN005"]
        assert "VMEM footprint" in out[0].message

    def test_build_failure_krn000(self):
        def build(case):
            raise RuntimeError("shape arithmetic broke")
        out = krn.check_contract(_contract(build), ROOT)
        assert _rules(out) == ["KRN000"]
        assert "shape arithmetic broke" in out[0].message

    def test_real_kernel_contracts_are_clean(self):
        # all four kernel packages: contracts exist and prove out
        assert krn.run(ROOT) == []

    def test_decode_contract_matches_wrapper_arithmetic(self):
        # the shard-local clamp case: fit_block_k(160) -> 256, one
        # padded block — the contract must reproduce it, or the proof
        # covers a grid the kernel never runs
        from repro.kernels.decode_attention.ops import (CONTRACTS,
                                                        fit_block_k)
        decode = next(c for c in CONTRACTS
                      if c.name == "decode_attention")
        inst = decode.build({"b": 1, "s": 160, "h": 8, "kvh": 8,
                             "d": 64})
        assert fit_block_k(160) == 256
        k = next(op for op in inst.inputs if op.name == "k")
        assert k.shape[1] == 256 and k.block[1] == 256
        assert inst.grid == (8, 1)


# --- Pass 2: jit purity (PUR) --------------------------------------------

class TestPurityRules:
    def test_item_in_jit_pur001(self, tmp_path):
        _write_tree(tmp_path, "f.py", """
            import jax

            @jax.jit
            def step(x):
                return x.item()
        """)
        out = pur.run(str(tmp_path), subdirs=("f.py",))
        assert _rules(out) == ["PUR001"]
        f = out[0]
        assert f.line == 6 and ".item()" in f.message
        assert "step" in f.message

    def test_impl_suffix_is_traced(self, tmp_path):
        _write_tree(tmp_path, "f.py", """
            def decode_impl(x, n):
                return float(x)
        """)
        out = pur.run(str(tmp_path), subdirs=("f.py",))
        assert _rules(out) == ["PUR001"]
        assert "'float(x)'" in out[0].message

    def test_branch_on_traced_pur002(self, tmp_path):
        _write_tree(tmp_path, "f.py", """
            import jax

            @jax.jit
            def gate(x):
                if x > 0:
                    return x
                return -x
        """)
        out = pur.run(str(tmp_path), subdirs=("f.py",))
        assert _rules(out) == ["PUR002"]
        assert "lax.cond" in out[0].hint

    def test_none_presence_branch_not_pur002(self, tmp_path):
        """``x is None`` / ``x is not None`` on a traced parameter is a
        structural pytree-presence test (e.g. an optional page-table
        argument), resolved per trace — never a tracer in boolean
        context, so it must not fire PUR002."""
        _write_tree(tmp_path, "f.py", """
            import jax

            @jax.jit
            def splice(cache, pages):
                if pages is not None:
                    return cache + pages
                if pages is None:
                    return cache
        """)
        out = pur.run(str(tmp_path), subdirs=("f.py",))
        assert _rules(out) == []

    def test_static_argnames_exempt(self, tmp_path):
        _write_tree(tmp_path, "f.py", """
            import jax
            from functools import partial

            @partial(jax.jit, static_argnames=("n",))
            def gate(x, n):
                if n > 4:
                    return x * 2
                return x
        """)
        assert pur.run(str(tmp_path), subdirs=("f.py",)) == []

    def test_shape_branch_is_static(self, tmp_path):
        _write_tree(tmp_path, "f.py", """
            import jax

            @jax.jit
            def pad(x):
                if x.shape[0] % 8:
                    return x
                return x * 2
        """)
        assert pur.run(str(tmp_path), subdirs=("f.py",)) == []

    def test_mutable_default_pur003(self, tmp_path):
        _write_tree(tmp_path, "f.py", """
            import dataclasses

            @dataclasses.dataclass
            class Spec:
                rate: float = 1.0

            @dataclasses.dataclass
            class Meter:
                spec: Spec = Spec()
                tags: list = []
        """)
        out = pur.run(str(tmp_path), subdirs=("f.py",))
        assert _rules(out) == ["PUR003", "PUR003"]
        assert "shared 'Spec()' instance" in out[0].message
        assert "default_factory" in out[0].hint

    def test_frozen_default_is_legal(self, tmp_path):
        _write_tree(tmp_path, "f.py", """
            import dataclasses

            @dataclasses.dataclass(frozen=True)
            class Spec:
                rate: float = 1.0

            @dataclasses.dataclass
            class Meter:
                spec: Spec = Spec()
        """)
        assert pur.run(str(tmp_path), subdirs=("f.py",)) == []

    def test_key_reuse_pur004(self, tmp_path):
        _write_tree(tmp_path, "f.py", """
            import jax

            def init(key):
                a = jax.random.normal(key, (4,))
                b = jax.random.normal(key, (4,))
                return a, b
        """)
        out = pur.run(str(tmp_path), subdirs=("f.py",))
        assert _rules(out) == ["PUR004"]
        assert "first drawn at line 5" in out[0].message

    def test_key_split_and_exclusive_branches_ok(self, tmp_path):
        # split between draws, and draws in mutually-exclusive
        # if/return branches (the models/param.py shape), are not reuse
        _write_tree(tmp_path, "f.py", """
            import jax

            def init(key):
                a = jax.random.normal(key, (4,))
                key = jax.random.split(key)[0]
                b = jax.random.normal(key, (4,))
                return a, b

            def init_one(kind, key):
                if kind == "embed":
                    return jax.random.normal(key, (4,))
                if kind == "small":
                    return jax.random.normal(key, (4,))
                return jax.random.normal(key, (8,))
        """)
        assert pur.run(str(tmp_path), subdirs=("f.py",)) == []

    def test_loop_side_effect_pur005(self, tmp_path):
        _write_tree(tmp_path, "f.py", """
            import jax

            def scanit(xs, out):
                def body(i, c):
                    print(i)
                    out.append(c)
                    return c + 1
                return jax.lax.fori_loop(0, 4, body, 0)
        """)
        out = pur.run(str(tmp_path), subdirs=("f.py",))
        assert _rules(out) == ["PUR005", "PUR005"]
        assert any("jax.debug.print" in f.hint for f in out)
        assert any("carry" in f.hint for f in out)


# --- Pass 3: units (UNT) -------------------------------------------------

class TestUnitRules:
    def run(self, tmp_path, body):
        _write_tree(tmp_path, "f.py", body)
        return unt.run(str(tmp_path), subdirs=("f.py",))

    def test_watts_plus_joules_unt001(self, tmp_path):
        out = self.run(tmp_path, """
            def total(watts, joules):
                return watts + joules
        """)
        assert _rules(out) == ["UNT001"]
        f = out[0]
        assert "'watts + joules'" in f.message       # expression quoted
        assert f.line == 3

    def test_ms_vs_s_comparison_unt001(self, tmp_path):
        out = self.run(tmp_path, """
            def clipped(t_ms, start_s):
                return t_ms >= start_s
        """)
        assert _rules(out) == ["UNT001"]
        assert "ms" in out[0].message

    def test_energy_from_mean_watts_unt002(self, tmp_path):
        out = self.run(tmp_path, """
            import numpy as np

            def report(watts):
                energy_j = np.mean(watts)
                return energy_j
        """)
        assert _rules(out) == ["UNT002"]
        assert "energy = integral of power" in out[0].hint

    def test_kwarg_mismatch_unt003(self, tmp_path):
        out = self.run(tmp_path, """
            def go(t_ms, measure):
                measure(duration_s=t_ms)
        """)
        assert _rules(out) == ["UNT003"]
        assert "divide the milliseconds by 1e3" in out[0].hint

    def test_return_mismatch_unt004(self, tmp_path):
        out = self.run(tmp_path, """
            def delay_s(backoff_ms):
                return backoff_ms
        """)
        assert _rules(out) == ["UNT004"]

    def test_correct_dimensional_algebra_is_clean(self, tmp_path):
        # W*s=J, J/s=W, 1/hz=s, ms/1e3 rescales, literals are free
        out = self.run(tmp_path, """
            import numpy as np

            SLO_S = 5.0
            TARGET_QPS = 200.0

            def summarize(watts, window_s, t_ms, sample_hz):
                energy_j = float(np.trapezoid(watts, t_ms / 1e3))
                avg_w = energy_j / max(window_s, 1e-12)
                period_s = 1.0 / sample_hz
                tok_per_j = 4096.0 / energy_j
                deadline_ms = SLO_S * 1e3
                return energy_j, avg_w, period_s, tok_per_j, deadline_ms
        """)
        assert out == []

    def test_unit_propagates_through_locals(self, tmp_path):
        out = self.run(tmp_path, """
            import numpy as np

            def report(watts):
                avg = np.mean(watts)
                energy_j = avg
                return energy_j
        """)
        assert _rules(out) == ["UNT002"]

    def test_per_name_parsing(self, tmp_path):
        out = self.run(tmp_path, """
            def eff(tok_per_j, energy_j):
                watts = tok_per_j * energy_j
                return watts
        """)
        # tokens/J * J = dimensionless, assigned to a watts name
        assert _rules(out) == ["UNT002"]

    def test_joules_plus_watt_hours_unt001(self, tmp_path):
        # Wh is scale-tagged joules (3.6e3 J): adding it to raw J
        # without converting is the classic 3600x billing bug
        out = self.run(tmp_path, """
            def total(energy_j, energy_wh):
                return energy_j + energy_wh
        """)
        assert _rules(out) == ["UNT001"]
        assert "Wh" in out[0].message

    def test_kwh_conversion_is_clean(self, tmp_path):
        # explicit rescaling by the literal factor forgets the scale
        # tag, so J / 3.6e6 may be named kwh (and Wh * 3.6e3 named j)
        out = self.run(tmp_path, """
            def bill(energy_j, energy_wh):
                energy_kwh = energy_j / 3.6e6
                back_j = energy_wh * 3.6e3
                return energy_kwh, back_j
        """)
        assert out == []

    def test_gco2_from_kwh_times_intensity_is_clean(self, tmp_path):
        # kWh (3.6e6-tagged J) * gCO2/kWh (g per 3.6e6 J) = plain grams
        out = self.run(tmp_path, """
            def footprint(energy_kwh, intensity_gco2_per_kwh):
                emitted_gco2 = energy_kwh * intensity_gco2_per_kwh
                return emitted_gco2
        """)
        assert out == []

    def test_gco2_from_raw_joules_flags(self, tmp_path):
        # J * gCO2/kWh keeps the 1/3.6e6 scale: naming it plain
        # gCO2 without the kWh conversion is off by 3.6e6
        out = self.run(tmp_path, """
            def footprint(energy_j, intensity_gco2_per_kwh):
                emitted_gco2 = energy_j * intensity_gco2_per_kwh
                return emitted_gco2
        """)
        assert _rules(out) == ["UNT002"]

    def test_intensity_returned_as_grams_unt004(self, tmp_path):
        out = self.run(tmp_path, """
            def emitted_gco2(intensity_gco2_per_kwh):
                return intensity_gco2_per_kwh
        """)
        assert _rules(out) == ["UNT004"]

    def test_kwh_kwarg_mismatch_hint_unt003(self, tmp_path):
        out = self.run(tmp_path, """
            def go(energy_j, bill):
                bill(energy_kwh=energy_j)
        """)
        assert _rules(out) == ["UNT003"]
        assert "divide the joules by 3.6e6" in out[0].hint


# --- suppression, baseline, runner, CLI ----------------------------------

class TestFindingModel:
    def test_fingerprint_is_line_insensitive(self):
        a = Finding("UNT001", "error", "x.py", 10, "msg  here", obj="f")
        b = Finding("UNT001", "error", "x.py", 99, "msg here", obj="f")
        assert a.fingerprint == b.fingerprint
        assert a.format().startswith("x.py:10: UNT001")

    def test_noqa_parsing(self):
        src = ("a = 1\n"
               "b = watts + joules  # repro: noqa[UNT001]\n"
               "c = 2  # repro: noqa\n"
               "d = 3  # repro: noqa[KRN001, PUR002]\n")
        supp = file_suppressions(src)
        assert supp == {2: frozenset({"UNT001"}), 3: None,
                        4: frozenset({"KRN001", "PUR002"})}
        f2 = Finding("UNT001", "error", "x.py", 2, "m")
        f2b = Finding("UNT002", "error", "x.py", 2, "m")
        f3 = Finding("PUR004", "error", "x.py", 3, "m")
        assert is_suppressed(f2, supp)
        assert not is_suppressed(f2b, supp)      # wrong rule listed
        assert is_suppressed(f3, supp)           # bare form: any rule

    def test_inline_suppression_end_to_end(self, tmp_path):
        _write_tree(tmp_path, "f.py", """
            def total(watts, joules):
                return watts + joules  # repro: noqa[UNT001]
        """)
        assert run_all(str(tmp_path), rules=("UNT",)) == []

    def test_baseline_roundtrip_and_justification(self, tmp_path):
        path = str(tmp_path / "lint.json")
        f = Finding("UNT001", "error", "x.py", 3, "watts + joules")
        save_baseline(path, [f])
        base = load_baseline(path)
        assert base[f.fingerprint]["justification"] == UNREVIEWED
        base[f.fingerprint]["justification"] = "legacy scalar API"
        with open(path, "w") as fh:
            json.dump({"version": 1, "findings": base}, fh)
        # refresh keeps the reviewed justification
        save_baseline(path, [f], previous=load_baseline(path))
        assert (load_baseline(path)[f.fingerprint]["justification"]
                == "legacy scalar API")

    def test_gate_both_directions(self):
        old = Finding("UNT001", "error", "x.py", 3, "old finding")
        new = Finding("UNT002", "error", "y.py", 7, "new finding")
        baseline = {old.fingerprint: {"rule": "UNT001", "path": "x.py",
                                      "justification": "known"}}
        got_new, stale = gate([old, new], baseline)
        assert [f.fingerprint for f in got_new] == [new.fingerprint]
        assert stale == []
        # the baselined finding vanished: the ratchet flags it
        got_new, stale = gate([new], baseline)
        assert stale == [old.fingerprint]


class TestCLI:
    def _cli(self, *args, cwd=ROOT):
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(ROOT, "src"))
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis", *args],
            capture_output=True, text=True, env=env, cwd=cwd)

    def test_live_repo_is_clean_under_fail_on_new(self):
        # the acceptance criterion: the PR tree passes its own gate
        r = self._cli("--fail-on-new")
        assert r.returncode == 0, r.stdout + r.stderr
        assert "0 new" in r.stdout

    def test_unknown_rule_prefix_is_usage_error(self):
        r = self._cli("--rules", "XYZ")
        assert r.returncode == 2
        assert "unknown rule prefix" in r.stderr

    def test_new_finding_fails_gate_with_hint(self, tmp_path):
        core = tmp_path / "src" / "repro" / "core"
        core.mkdir(parents=True)
        _write_tree(core, "bad.py", """
            def total(watts, joules):
                return watts + joules
        """)
        r = self._cli("--root", str(tmp_path), "--rules", "UNT",
                      "--baseline", str(tmp_path / "lint.json"),
                      "--fail-on-new")
        assert r.returncode == 1
        assert "UNT001" in r.stdout
        assert "--update-baseline" in r.stderr

    def test_update_baseline_then_gate_passes_then_stale_fails(
            self, tmp_path):
        core = tmp_path / "src" / "repro" / "core"
        core.mkdir(parents=True)
        bad = _write_tree(core, "bad.py", """
            def total(watts, joules):
                return watts + joules
        """)
        baseline = str(tmp_path / "lint.json")
        common = ("--root", str(tmp_path), "--rules", "UNT",
                  "--baseline", baseline)
        r = self._cli(*common, "--update-baseline")
        assert r.returncode == 0 and "1 finding" in r.stdout
        assert UNREVIEWED.split(" ")[0] in open(baseline).read()
        r = self._cli(*common, "--fail-on-new")
        assert r.returncode == 0, r.stdout + r.stderr
        # fix the finding without refreshing the baseline: stale gate
        os.unlink(bad)
        r = self._cli(*common, "--fail-on-new")
        assert r.returncode == 1
        assert "no longer fire" in r.stderr
        assert "stale:" in r.stderr

    def test_out_writes_findings_json(self, tmp_path):
        out = str(tmp_path / "findings.json")
        r = self._cli("--rules", "UNT", "--out", out)
        assert r.returncode == 0
        data = json.load(open(out))
        assert "findings" in data and "baseline" in data


# --- the meta-test: the whole live tree, all three passes ----------------

def test_live_tree_is_clean():
    """Every pre-existing finding in this repo is fixed or baselined;
    run_all over the real tree plus the committed baseline gate must
    come back empty."""
    findings = run_all(ROOT)
    baseline = load_baseline(
        os.path.join(ROOT, "benchmarks", "baselines", "lint.json"))
    new, stale = gate(findings, baseline)
    assert new == [], "\n".join(f.format() for f in new)
    assert stale == [], stale
