"""Negative compliance tests: one per invariant R1-R13.

Each test hand-builds a minimal *valid* submission log, tampers with
exactly the aspect one invariant guards, and proves ``review()``
REJECTS the run with that invariant named — i.e. a faulted or forged
run can never slip through as a plausible-but-wrong number.
"""
import dataclasses

import numpy as np

from repro.core.compliance import SystemDescription, review
from repro.core.mlperf_log import LogEvent, MLPerfLogger
from repro.power import PSUModel

HZ = 10.0
DUR_S = 65.0
RAILS = {"accelerator": 20.0, "host": 10.0}  # DC load: 30 W


class _StackStub:
    """Just enough MeterStack surface for review(): a documented PSU
    model (enables R10) with no channel registry (KeyError falls back
    to the default analyzer gain slack)."""

    def __init__(self, psu):
        self.psu = psu

    def channel(self, name):
        raise KeyError(name)


def _perf_events(duration_s=DUR_S):
    log = MLPerfLogger("perf")
    log.run_start(0.0)
    log.result("samples_processed", 240, duration_s * 1e3)
    log.run_stop(duration_s * 1e3)
    return log.events


def _power_events(duration_s=DUR_S, psu=None):
    psu = psu or PSUModel(rated_watts=100.0, efficiency=0.9)
    log = MLPerfLogger("power")
    t_ms = np.arange(0.0, duration_s + 1e-9, 1.0 / HZ) * 1e3
    for name, watts in RAILS.items():
        for ti in t_ms:
            log.power_sample(ti, watts, node=name,
                             extra={"kind": name, "group": "",
                                    "boundary": False, "sample_hz": HZ})
    wall_w = float(psu.wall_watts(sum(RAILS.values())))
    for ti in t_ms:
        log.power_sample(ti, wall_w, node="wall",
                         extra={"kind": "wall", "group": "",
                                "boundary": True, "sample_hz": HZ})
    return log.events, psu


def _sysdesc(**kw):
    base = dict(scale="edge", max_system_watts=100,
                idle_system_watts=8)
    base.update(kw)
    return SystemDescription(**base)


def _review(perf=None, power=None, sysdesc=None, psu=None, **kw):
    if power is None:
        power, psu = _power_events()
    return review(perf if perf is not None else _perf_events(),
                  power, sysdesc or _sysdesc(),
                  meter_stack=_StackStub(psu) if psu else None, **kw)


def _assert_rejected(report, rule):
    failed = [c.rule for c in report.failures()]
    assert not report.passed, f"expected a {rule} rejection"
    assert any(r.startswith(rule) for r in failed), \
        f"{rule} not named in failures {failed}"
    assert "=> REJECTED" in report.render()


def _drop(events, node, lo_s, hi_s):
    return [ev for ev in events
            if not (ev.key == "power_w"
                    and (ev.metadata or {}).get("node") == node
                    and lo_s * 1e3 <= ev.time_ms <= hi_s * 1e3)]


def _scale_node(events, node, factor):
    out = []
    for ev in events:
        if ev.key == "power_w" and \
                (ev.metadata or {}).get("node") == node:
            ev = LogEvent(ev.key, ev.value * factor, ev.time_ms,
                          ev.namespace, ev.metadata)
        out.append(ev)
    return out


def test_untampered_baseline_accepted():
    rep = _review()
    assert rep.passed, rep.render()
    assert "=> ACCEPTED" in rep.render()


def test_r1_short_window_rejected():
    power, psu = _power_events(duration_s=30.0)
    _assert_rejected(
        _review(perf=_perf_events(duration_s=30.0), power=power,
                psu=psu), "R1")


def test_r2_undersampled_rejected():
    power, psu = _power_events()
    # keep every 20th sample per channel: 0.5 Hz/node vs required 1 Hz
    kept, i = [], {}
    for ev in power:
        if ev.key != "power_w":
            kept.append(ev)
            continue
        node = (ev.metadata or {}).get("node")
        if i.setdefault(node, 0) % 20 == 0:
            kept.append(ev)
        i[node] += 1
    _assert_rejected(_review(power=kept, psu=psu), "R2")


def test_r3_telemetry_gap_rejected():
    power, psu = _power_events()
    # a 10 s hole in one node's samples: > 1.5x the allowed 2 s gap
    _assert_rejected(
        _review(power=_drop(power, "accelerator", 20.0, 30.0), psu=psu),
        "R3")


def test_r4_unapproved_instrument_rejected_edge():
    _assert_rejected(
        _review(sysdesc=_sysdesc(instrument_spec_approved=False)), "R4")


def test_r4_undocumented_telemetry_rejected_datacenter():
    _assert_rejected(
        _review(sysdesc=_sysdesc(scale="datacenter",
                                 telemetry_accuracy=None)), "R4")


def test_r5_partial_scope_rejected():
    _assert_rejected(_review(sysdesc=_sysdesc(scope=("chips",))), "R5")


def test_r6_undocumented_estimation_rejected():
    _assert_rejected(
        _review(sysdesc=_sysdesc(
            estimated_components={"interconnect": ""})), "R6")


def test_r7_average_outside_envelope_rejected():
    # declared envelope tops out at 20 W; the wall averages ~33 W
    _assert_rejected(_review(sysdesc=_sysdesc(max_system_watts=20)),
                     "R7")


def test_r8_autorange_on_sub75w_edge_rejected():
    _assert_rejected(_review(range_mode_used=False), "R8")


def test_r9_wall_below_rails_rejected():
    power, psu = _power_events()
    # halved wall readings claim less energy than the DC rails drew
    _assert_rejected(_review(power=_scale_node(power, "wall", 0.5),
                             psu=psu), "R9")


def test_r10_psu_inconsistency_rejected():
    power, psu = _power_events()
    # +20% wall still exceeds the rails (R9 passes) but contradicts
    # the documented PSU efficiency model
    rep = _review(power=_scale_node(power, "wall", 1.2), psu=psu)
    _assert_rejected(rep, "R10")
    assert all(c.passed for c in rep.checks
               if c.rule.startswith("R9"))


def test_r10_timeline_mismatch_rejected():
    power, psu = _power_events()
    # uncured dropout leaves the wall on a different sample grid than
    # the rails; R10 refuses to compare mismatched timelines
    rep = _review(power=_drop(power, "wall", 20.0, 21.0), psu=psu,
                  coverage_threshold=0.90)
    _assert_rejected(rep, "R10")
    assert any("timeline" in c.detail for c in rep.failures())


def test_r11_pdu_sum_mismatch_rejected():
    # fleet-style log: two replica walls + the derived PDU register
    log = MLPerfLogger("power")
    t_ms = np.arange(0.0, DUR_S + 1e-9, 1.0 / HZ) * 1e3
    for node, watts in (("r0/wall", 16.0), ("r1/wall", 14.0)):
        for ti in t_ms:
            log.power_sample(ti, watts, node=node,
                             extra={"kind": "wall", "group": node[:2],
                                    "boundary": False, "sample_hz": HZ})
    for ti in t_ms:
        log.power_sample(ti, 30.0 * 1.01, node="pdu",  # tampered +1%
                         extra={"kind": "pdu", "group": "",
                                "boundary": True, "sample_hz": HZ,
                                "source": "derived:r0/wall+r1/wall"})
    _assert_rejected(_review(power=log.events), "R11")


def test_r12_boundary_dropout_rejected():
    power, psu = _power_events()
    # 10% of the wall samples never delivered: coverage 90% < 95%
    _assert_rejected(
        _review(power=_drop(power, "wall", 20.0, 26.5), psu=psu),
        "R12")


def test_r12_breakdown_rail_dropout_tolerated():
    power, psu = _power_events()
    # same-sized hole in a non-boundary rail is NOT a validity hazard
    # (R3's gap check still guards the overall telemetry stream, so
    # keep the hole under its 3 s limit)
    power = _drop(power, "host", 20.0, 22.5)
    power = _drop(power, "host", 30.0, 32.5)
    power = _drop(power, "host", 40.0, 41.5)
    rep = _review(power=power, psu=psu)
    r12 = [c for c in rep.checks if c.rule.startswith("R12")]
    assert r12 and all(c.passed for c in r12)


def test_r13_clipped_boundary_samples_rejected():
    power, psu = _power_events()
    tampered = []
    for ev in power:
        md = ev.metadata or {}
        if ev.key == "power_w" and md.get("node") == "wall" \
                and 20e3 <= ev.time_ms <= 25e3:
            ev = LogEvent(ev.key, ev.value, ev.time_ms, ev.namespace,
                          dict(md, clipped=True))
        tampered.append(ev)
    rep = _review(power=tampered, psu=psu)
    _assert_rejected(rep, "R13")
    assert any("re-ranging" in c.detail for c in rep.failures())


def test_sysdesc_is_frozen_against_post_hoc_edits():
    # the review inputs themselves resist tampering: SystemDescription
    # is immutable, so a failed R4/R5 can't be patched after the fact
    sd = _sysdesc()
    if dataclasses.is_dataclass(sd) and \
            getattr(type(sd), "__dataclass_params__").frozen:
        import pytest
        with pytest.raises(dataclasses.FrozenInstanceError):
            sd.scale = "tiny"
