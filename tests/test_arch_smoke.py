"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduce_config
from repro.models import build_model
from repro.models.param import init_params


def _batch_for(cfg, b=2, s=64):
    key = jax.random.PRNGKey(0)
    tok = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    if cfg.vlm is not None:
        n_p = cfg.vlm.n_patches
        batch["tokens"] = tok[:, : s - n_p]
        batch["labels"] = tok[:, : s - n_p]
        batch["patch_embeds"] = jnp.ones((b, n_p, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((b, cfg.encdec.enc_len, cfg.d_model),
                                   jnp.float32)
    return batch


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduce_config(get_config(arch))
            model = build_model(cfg)
            params = init_params(model.param_defs(), jax.random.PRNGKey(1))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_no_nans(arch, arch_setup):
    cfg, model, params = arch_setup(arch)
    batch = _batch_for(cfg)
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert jnp.isfinite(loss), (arch, loss)
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_shapes(arch, arch_setup):
    cfg, model, params = arch_setup(arch)
    batch = _batch_for(cfg)
    if cfg.family == "encdec":
        inputs = {"frames": batch["frames"], "tokens": batch["tokens"]}
    else:
        inputs = {k: batch[k] for k in ("tokens", "patch_embeds")
                  if k in batch}
    logits, cache = jax.jit(
        lambda p, i: model.prefill(p, i, max_len=96))(params, inputs)
    vp = -(-cfg.vocab_size // 2048) * 2048
    assert logits.shape == (2, 1, vp)
    assert jnp.isfinite(logits).all()
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache = jax.jit(model.decode_step)(params, cache, tok)
    assert logits2.shape == (2, 1, vp)
    assert jnp.isfinite(logits2).all()
    assert int(cache["pos"]) == int(batch["tokens"].shape[1]) + \
        (cfg.vlm.n_patches if cfg.vlm is not None else 0) + 1


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "rwkv6-3b"])
def test_grad_step_updates_params(arch, arch_setup):
    from repro.train import init_train_state, make_train_step
    from repro.train.train_step import TrainHParams

    cfg, model, _ = arch_setup(arch)
    hp = TrainHParams(total_steps=4, warmup=1)
    state = init_train_state(model, jax.random.PRNGKey(0), hp)
    step = jax.jit(make_train_step(model, hp))
    batch = _batch_for(cfg)
    new_state, metrics = step(state, batch)
    # step 0 has lr=0 under warmup; take a second step so params move
    new_state, metrics = step(new_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(new_state.step) == 2
    # at least one parameter moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)),
                     state.params, new_state.params))
    assert moved


def test_prefill_matches_decode_consistency(arch_setup):
    """Decoding t tokens one-by-one == prefilling t+prompt (same arch)."""
    cfg, model, params = arch_setup("qwen3-1.7b")
    key = jax.random.PRNGKey(7)
    tok = jax.random.randint(key, (1, 16), 0, cfg.vocab_size)
    logits_a, cache = model.prefill(params, {"tokens": tok}, max_len=32)
    # feed two more tokens via decode; compare against fresh prefill
    t1 = jnp.asarray([[11]], jnp.int32)
    t2 = jnp.asarray([[23]], jnp.int32)
    l1, cache = model.decode_step(params, cache, t1)
    l2, cache = model.decode_step(params, cache, t2)
    full = jnp.concatenate([tok, t1, t2], axis=1)
    logits_b, _ = model.prefill(params, {"tokens": full}, max_len=32)
    import numpy as np
    np.testing.assert_allclose(l2[:, -1], logits_b[:, -1], rtol=2e-2,
                               atol=2e-2)


def test_tiny_model_runs():
    from repro.models.tiny import IN_F, IN_T, TinyModel

    cfg = get_config("tiny-kws")
    model = TinyModel(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    x = jnp.ones((4, IN_T, IN_F))
    logits = model(params, x)
    assert logits.shape == (4, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    assert model.macs > 0 and model.sram_bytes > 0
