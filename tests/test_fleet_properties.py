"""Property tests for the fleet arrival-trace generators.

Deterministic invariants run unconditionally; hypothesis widens the
same invariants over the parameter space when the optional dep is
installed (CI has it; the pinned container may not).
"""
import numpy as np
import pytest

from repro.fleet import (CarbonTrace, TRACES, bursty_trace, diurnal_trace,
                         ramp_trace)


def _all_traces(seed=0, horizon_s=2000.0):
    return [
        diurnal_trace(peak_qps=0.08, trough_qps=0.01,
                      horizon_s=horizon_s, period_s=horizon_s, seed=seed),
        bursty_trace(base_qps=0.02, burst_qps=0.1, burst_period_s=500.0,
                     burst_duration_s=100.0, horizon_s=horizon_s,
                     seed=seed),
        ramp_trace(start_qps=0.01, end_qps=0.1, horizon_s=horizon_s,
                   seed=seed),
    ]


def test_registry_covers_generators():
    assert set(TRACES) == {"diurnal", "bursty", "ramp"}


def test_arrivals_sorted_in_horizon_non_negative_gaps():
    for tr in _all_traces():
        a = tr.arrivals_s
        assert a.size > 0, tr.label
        assert float(a[0]) >= 0.0
        assert float(a[-1]) <= tr.horizon_s
        assert np.all(np.diff(a) >= 0.0), tr.label


def test_seeded_determinism_and_seed_sensitivity():
    for a, b in zip(_all_traces(seed=7), _all_traces(seed=7)):
        assert np.array_equal(a.arrivals_s, b.arrivals_s), a.label
    # different seed -> different sample path (same process)
    for a, b in zip(_all_traces(seed=7), _all_traces(seed=8)):
        assert not np.array_equal(a.arrivals_s, b.arrivals_s), a.label


def test_compression_conserves_count_and_order():
    for tr in _all_traces():
        for factor in (2.0, 86400.0 / 180.0):
            c = tr.compress(factor)
            assert c.n_arrivals == tr.n_arrivals, tr.label
            assert c.horizon_s == pytest.approx(tr.horizon_s / factor)
            assert np.all(np.diff(c.arrivals_s) >= 0.0)
            # compression scales time, not structure
            assert np.allclose(c.arrivals_s * factor, tr.arrivals_s)
            # mean rate scales inversely with the horizon
            assert c.mean_qps == pytest.approx(tr.mean_qps * factor)


def test_diurnal_peak_exceeds_trough_rate():
    tr = diurnal_trace(peak_qps=0.1, trough_qps=0.005, horizon_s=86400.0,
                       period_s=86400.0, seed=3)
    # trough at t=0 (raised cosine), peak half a period in
    trough = tr.rate_qps(0.0, window_s=8640.0)
    peak = tr.rate_qps(43200.0, window_s=8640.0)
    assert peak > 3.0 * max(trough, 1e-9)


def test_bursty_duration_validation():
    with pytest.raises(ValueError):
        bursty_trace(base_qps=0.01, burst_qps=0.1, burst_period_s=100.0,
                     burst_duration_s=200.0, horizon_s=1000.0)


def test_carbon_trace_intensity_and_emissions():
    ct = CarbonTrace(base_gco2_per_kwh=450.0, swing_gco2_per_kwh=250.0,
                     period_s=86400.0)
    # base + swing*cos: max at t=0, min half a period in
    assert ct.intensity_gco2_per_kwh(0.0) == pytest.approx(700.0)
    assert ct.intensity_gco2_per_kwh(43200.0) == pytest.approx(200.0)
    # 1 kWh at the peak emits 700 g
    assert ct.emitted_gco2(3.6e6, 0.0) == pytest.approx(700.0)
    # emissions are additive over samples
    e = ct.emitted_gco2(np.array([3.6e6, 3.6e6]), np.array([0.0, 43200.0]))
    assert e == pytest.approx(900.0)


# --- hypothesis widening (optional dep) ----------------------------------
# guarded per-section (not module-level importorskip) so the
# deterministic invariants above still run where hypothesis is absent

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    RATE = st.floats(min_value=1e-3, max_value=0.2, allow_nan=False)
    SEED = st.integers(min_value=0, max_value=2**31 - 1)

    @given(peak=RATE, frac=st.floats(min_value=0.01, max_value=1.0),
           seed=SEED)
    @settings(max_examples=50, deadline=None)
    def test_prop_diurnal_sorted_bounded(peak, frac, seed):
        tr = diurnal_trace(peak_qps=peak, trough_qps=peak * frac,
                           horizon_s=5000.0, period_s=5000.0, seed=seed)
        a = tr.arrivals_s
        if a.size:
            assert float(a[0]) >= 0.0 and float(a[-1]) <= tr.horizon_s
            assert np.all(np.diff(a) >= 0.0)

    @given(peak=RATE, seed=SEED,
           factor=st.floats(min_value=1.001, max_value=1e4))
    @settings(max_examples=50, deadline=None)
    def test_prop_compress_conserves_count(peak, seed, factor):
        tr = diurnal_trace(peak_qps=peak, trough_qps=peak / 4,
                           horizon_s=4000.0, period_s=4000.0, seed=seed)
        c = tr.compress(factor)
        assert c.n_arrivals == tr.n_arrivals
        assert c.horizon_s == pytest.approx(tr.horizon_s / factor)
        assert np.all(np.diff(c.arrivals_s) >= 0.0)

    @given(start=RATE, end=RATE, seed=SEED)
    @settings(max_examples=50, deadline=None)
    def test_prop_ramp_deterministic_per_seed(start, end, seed):
        a = ramp_trace(start_qps=start, end_qps=end, horizon_s=3000.0,
                       seed=seed)
        b = ramp_trace(start_qps=start, end_qps=end, horizon_s=3000.0,
                       seed=seed)
        assert np.array_equal(a.arrivals_s, b.arrivals_s)
