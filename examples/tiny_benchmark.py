"""MLPerf-Tiny-scale benchmark: keyword spotting, single-stream, with
pin-demarcated energy capture through the I/O manager — the µW end of
the paper's range.  Reports energy/inference and the 1/Joules metric.

  PYTHONPATH=src python examples/tiny_benchmark.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (Clock, IOManager, MLPerfLogger, QuerySampleLibrary,
                        SystemDescription, TinyPowerModel, review,
                        run_single_stream, summarize)
from repro.models import tiny as tiny_mod
from repro.models.param import init_params


def main():
    cfg = get_config("tiny-kws")
    model = tiny_mod.TinyModel(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    fwd = jax.jit(lambda p, x: model(p, x))
    x = jnp.ones((1, tiny_mod.IN_T, tiny_mod.IN_F))
    fwd(params, x).block_until_ready()

    # --- real single-stream latency on this CPU
    def issue(sample):
        t0 = time.perf_counter()
        fwd(params, x).block_until_ready()
        return time.perf_counter() - t0

    qsl = QuerySampleLibrary(64, lambda i: {"idx": i})
    res = run_single_stream(issue, qsl, clock=Clock(), min_queries=300)
    print(f"single-stream: {res.n_queries} inferences, "
          f"p50 {res.p50 * 1e6:.0f} µs, p90 {res.p90 * 1e6:.0f} µs")

    # --- MCU energy model + I/O-manager capture
    tm = TinyPowerModel()
    macs, sram = tiny_mod.macs(cfg), tiny_mod.sram_bytes(cfg)
    print(f"workload: {macs / 1e3:.0f}k MACs, {sram / 1024:.0f} KiB SRAM")
    period = 0.25                        # always-on detector, 4 Hz frames
    t, amps, pin = tm.waveform(macs, sram, n_inferences=256,
                               period_s=period, sample_hz=50_000)
    io = IOManager()
    e_inf, n = io.energy_per_inference(t, amps, pin)
    duty = tm.duty_cycle(macs, period)
    avg_w = e_inf / period + tm.device.sleep_watts
    print(f"captured {n} pin windows: {e_inf * 1e6:.2f} µJ/inference, "
          f"1/J metric = {1.0 / e_inf:.0f}")
    print(f"duty cycle {duty * 100:.2f}% -> average power "
          f"{avg_w * 1e6:.1f} µW (µW regime, Fig. 2)")

    # --- standardized logs + compliance
    perf = MLPerfLogger("perf")
    perf.run_start(0.0)
    perf.result("samples_processed", n, n * period * 1e3)
    perf.run_stop(n * period * 1e3)
    power = MLPerfLogger("power")
    stride = max(1, len(t) // 64000)
    for ti, ai in zip(t[::stride], amps[::stride]):
        power.power_sample(ti * 1e3, ai * tm.device.supply_volts)
    s = summarize(perf.events, power.events)
    print(f"summarizer: {s.energy_j * 1e3:.2f} mJ total, "
          f"{s.inv_joules:.1f} samples/J")
    rep = review(perf.events, power.events,
                 SystemDescription(scale="tiny", instrument="io-manager",
                                   max_system_watts=0.01,
                                   idle_system_watts=5e-5))
    print(rep.render())


if __name__ == "__main__":
    main()
