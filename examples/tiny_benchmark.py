"""MLPerf-Tiny-scale benchmark: keyword spotting, single-stream, with
duty-cycled MCU energy capture — the µW end of the paper's range.

The jitted forward runs for real on this CPU (true latencies); the
energy side models an always-on detector at 4 Hz frames behind
``TinySUT``, whose power source replays the MCU waveform (active burst
per frame, sleep floor between).  ``PowerRun`` drives the whole
methodology — loadgen, Director + µW-class analyzer, summarizer,
compliance — in one call, and the I/O manager cross-checks the
per-inference energy from the pin-demarcated waveform.

  PYTHONPATH=src python -m examples.tiny_benchmark
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import IOManager, TinyPowerModel
from repro.harness import PowerRun, SingleStream, TinySUT
from repro.models import tiny as tiny_mod
from repro.models.param import init_params


def main(min_duration_s: float = 60.0):
    cfg = get_config("tiny-kws")
    model = tiny_mod.TinyModel(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    fwd = jax.jit(lambda p, x: model(p, x))
    x = jnp.ones((1, tiny_mod.IN_T, tiny_mod.IN_F))
    fwd(params, x).block_until_ready()

    macs, sram = tiny_mod.macs(cfg), tiny_mod.sram_bytes(cfg)
    print(f"workload: {macs / 1e3:.0f}k MACs, {sram / 1024:.0f} KiB SRAM")

    # --- one measured run: real forward latency, modeled 4 Hz detector
    period = 0.25                        # always-on detector, 4 Hz frames
    sut = TinySUT(lambda: fwd(params, x).block_until_ready(),
                  macs=macs, sram_bytes=sram, period_s=period,
                  name="tiny-kws")
    scenario = SingleStream(min_duration_s=min_duration_s,
                            min_queries=int(min_duration_s / period))
    r = PowerRun(sut, scenario, seed=0).run()

    lat = np.asarray(sut.real_latencies_s)
    print(f"single-stream: {len(lat)} inferences, "
          f"p50 {np.percentile(lat, 50) * 1e6:.0f} µs, "
          f"p90 {np.percentile(lat, 90) * 1e6:.0f} µs (real CPU)")

    n = r.outcome.result.n_queries
    e_inf = r.summary.energy_j / n
    tm = sut.model
    duty = tm.duty_cycle(macs, period)
    print(f"measured: {r.summary.energy_j * 1e3:.2f} mJ over "
          f"{r.summary.window_s:.0f} s -> {e_inf * 1e6:.2f} µJ/inference, "
          f"1/J metric = {1.0 / e_inf:.0f}")
    print(f"duty cycle {duty * 100:.2f}% -> average power "
          f"{r.summary.avg_watts * 1e6:.1f} µW (µW regime, Fig. 2)")

    # --- I/O-manager cross-check on the pin-demarcated waveform
    t, amps, pin = TinyPowerModel().waveform(
        macs, sram, n_inferences=min(n, 64), period_s=period,
        sample_hz=50_000)
    e_pin, n_pin = IOManager().energy_per_inference(t, amps, pin)
    print(f"io-manager cross-check: {n_pin} pin windows, "
          f"{e_pin * 1e6:.2f} µJ/inference")
    print(r.report.render())
    return r


if __name__ == "__main__":
    main()
