"""Serving under measurement: batched requests through the ServeEngine,
driven by the loadgen Offline + Server scenarios, measured by the
Director/analyzer protocol, summarized to Samples/Joule.

  PYTHONPATH=src python examples/serve_power.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import (Clock, Director, QuerySampleLibrary, StepWork,
                        SystemDescription, SystemPowerModel, review,
                        run_offline, run_server, summarize)
from repro.hw import EDGE_SYSTEM
from repro.models import build_model
from repro.models.param import init_params
from repro.serving import Request, ServeEngine


def main():
    cfg = reduce_config(get_config("granite-3-2b"))
    model = build_model(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_len=96, batch_size=4)

    # real CPU timing of one batch (prefill + 8 decode steps)
    key = jax.random.PRNGKey(1)

    def make_batch(i):
        return [Request(rid=i * 4 + j,
                        prompt=jax.random.randint(
                            jax.random.fold_in(key, i * 4 + j), (16,),
                            0, cfg.vocab_size),
                        max_new_tokens=8) for j in range(4)]

    engine.run_batch(make_batch(0))               # warmup/compile

    def issue_batch(samples):
        t0 = time.perf_counter()
        engine.run_batch(make_batch(samples[0]["idx"]))
        return time.perf_counter() - t0

    qsl = QuerySampleLibrary(32, lambda i: {"idx": i})
    offline = run_offline(issue_batch, qsl, batch=4, clock=Clock(),
                          min_duration_s=60.0)
    print(f"Offline: {offline.n_queries} queries, "
          f"{offline.qps:.2f} samples/s, p90 {offline.p90 * 1e3:.1f} ms")

    server, slo_ok = run_server(
        lambda s: issue_batch([s]) / 4, qsl, target_qps=offline.qps * 0.6,
        latency_slo_s=10.0, clock=Clock())
    print(f"Server:  {server.qps:.2f} qps, p99 {server.p99 * 1e3:.1f} ms, "
          f"SLO met: {slo_ok}")

    # Director-measured energy for the offline run
    meter = SystemPowerModel(EDGE_SYSTEM, 1)
    work = StepWork(flops=2.0 * cfg.param_count() * 24,
                    hbm_bytes=2.0 * cfg.param_count())
    watts = meter.system_watts(work)
    d = Director(seed=0)

    def sut_run(log):
        log.run_start(0.0)
        log.result("samples_processed", offline.n_queries,
                   offline.duration_s * 1e3)
        log.run_stop(offline.duration_s * 1e3)
        return offline.duration_s

    perf_log, power_log = d.run_measurement(
        sut_run=sut_run, power_source=lambda t: np.full_like(t, watts))
    s = summarize(perf_log.events, power_log.events)
    print(f"energy: {s.energy_j:.1f} J -> "
          f"{s.samples_per_joule:.4f} samples/J")
    rep = review(perf_log.events, power_log.events,
                 SystemDescription(scale="edge", max_system_watts=60,
                                   idle_system_watts=8))
    print(rep.render())


if __name__ == "__main__":
    main()
