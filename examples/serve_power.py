"""Serving under measurement, both engines:

1. Offline scenario — the fixed-batch ``ServeEngine`` issues blocking
   batches through ``run_offline`` (throughput-bound, the seed path).
2. Server scenario — Poisson arrivals feed the admission queue of the
   slot-based ``ContinuousBatchingEngine`` (``run_server_queue``).
   Finished slots are refilled mid-flight and decoding runs in
   on-device chunks (one host sync per chunk), so the reported
   TTFT/TPOT reflect real queueing + continuous batching, not
   batch-of-stragglers lockstep.  The Director's power samples are then
   attributed per request (``attribute_request_energy``).

  PYTHONPATH=src python examples/serve_power.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import (Clock, Director, QuerySampleLibrary, StepWork,
                        SystemDescription, SystemPowerModel, review,
                        run_offline, run_server_queue, summarize)
from repro.hw import EDGE_SYSTEM
from repro.models import build_model
from repro.models.param import init_params
from repro.serving import (ContinuousBatchingEngine, Request, ServeEngine,
                           attribute_request_energy)


def main():
    cfg = reduce_config(get_config("granite-3-2b"))
    model = build_model(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)

    def make_req(i, arrival_s=0.0, new_tokens=8):
        return Request(rid=i,
                       prompt=jax.random.randint(
                           jax.random.fold_in(key, i), (16,),
                           0, cfg.vocab_size),
                       max_new_tokens=new_tokens, arrival_s=arrival_s)

    # ------------------------------------------------------------------
    # Offline: fixed batches, real CPU timing of one batch per issue
    # ------------------------------------------------------------------
    engine = ServeEngine(model, params, max_len=96, batch_size=4)
    engine.run_batch([make_req(100 + j) for j in range(4)])  # compile

    def issue_batch(samples):
        t0 = time.perf_counter()
        engine.run_batch([make_req(4 * samples[0]["idx"] + j)
                          for j in range(4)])
        return time.perf_counter() - t0

    qsl = QuerySampleLibrary(32, lambda i: {"idx": i})
    offline = run_offline(issue_batch, qsl, batch=4, clock=Clock(),
                          min_duration_s=60.0)
    print(f"Offline: {offline.n_queries} queries, "
          f"{offline.qps:.2f} samples/s, p90 {offline.p90 * 1e3:.1f} ms")

    # ------------------------------------------------------------------
    # Server: Poisson arrivals -> continuous-batching admission queue.
    # Mixed token budgets make the fixed-batch straggler problem real;
    # the slot engine retires short requests early and refills.
    # ------------------------------------------------------------------
    cont = ContinuousBatchingEngine(model, params, max_len=96, n_slots=4,
                                    chunk_steps=4)
    cont.serve([make_req(200, new_tokens=4)],
               honor_arrivals=False)                  # warmup/compile
    done_box = {}

    def serve_fn(arrivals):
        reqs = [make_req(i, arrival_s=a, new_tokens=(4, 12, 8)[i % 3])
                for i, (_, a) in enumerate(arrivals)]
        done = cont.serve(reqs)
        done_box["reqs"] = done
        return done

    server = run_server_queue(serve_fn, qsl, target_qps=offline.qps * 2,
                              latency_slo_s=10.0, min_duration_s=0.5)
    res = server.result
    print(f"Server:  {res.qps:.2f} qps, {server.tokens_per_s:.1f} tok/s, "
          f"p99 {res.p99 * 1e3:.1f} ms, SLO met: {server.slo_met}")
    print(f"  TTFT p99 {server.ttft_p(99) * 1e3:.1f} ms, "
          f"TPOT mean {np.mean(server.tpot_s) * 1e3:.2f} ms, "
          f"host syncs {cont.host_syncs}")

    # ------------------------------------------------------------------
    # Director-measured energy for the Server run, per-request shares
    # ------------------------------------------------------------------
    meter = SystemPowerModel(EDGE_SYSTEM, 1)
    watts = meter.system_watts(StepWork(
        flops=2.0 * cfg.param_count() * server.tokens_per_s,
        hbm_bytes=2.0 * cfg.param_count()))
    d = Director(seed=0)

    def sut_run(log):
        log.run_start(0.0)
        log.result("samples_processed", res.n_queries,
                   res.duration_s * 1e3)
        log.run_stop(res.duration_s * 1e3)
        return res.duration_s

    perf_log, power_log = d.run_measurement(
        sut_run=sut_run, power_source=lambda t: np.full_like(t, watts))
    s = summarize(perf_log.events, power_log.events)
    samples = [(ev.time_ms / 1e3, float(ev.value))
               for ev in power_log.events if ev.key == "power_w"]
    per_req = attribute_request_energy(
        done_box["reqs"], np.asarray([t for t, _ in samples]),
        np.asarray([w for _, w in samples]))
    e = np.asarray(list(per_req.values()))
    print(f"energy: {s.energy_j:.1f} J -> "
          f"{s.samples_per_joule:.4f} samples/J, "
          f"{server.total_tokens / max(s.energy_j, 1e-9):.3f} tok/J, "
          f"per-request mean {e.mean():.2f} J")
    rep = review(perf_log.events, power_log.events,
                 SystemDescription(scale="edge", max_system_watts=60,
                                   idle_system_watts=8),
                 min_duration_s=0.5)
    print(rep.render())


if __name__ == "__main__":
    main()
