"""Serving under measurement, both engines, via ``repro.harness``:

1. Offline scenario — the fixed-batch ``ServeEngine`` behind
   ``ServeEngineSUT`` issues blocking batches (throughput-bound).
2. Server scenario — Poisson arrivals feed the admission queue of the
   slot-based ``ContinuousBatchingEngine`` (``ContinuousBatchingSUT``).
   Finished slots are refilled mid-flight and decoding runs in
   on-device chunks, so the reported TTFT/TPOT reflect real queueing +
   continuous batching.  ``PowerRun`` attributes the Director's power
   samples per request automatically (``per_request_energy_j``).

Each run is one call: ``PowerRun(sut, scenario).run()`` — loadgen,
Director protocol, summarizer, and compliance review included.

  PYTHONPATH=src python examples/serve_power.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.harness import (ContinuousBatchingSUT, Offline, PowerRun,
                           ServeEngineSUT, Server)
from repro.models import build_model
from repro.models.param import init_params
from repro.serving import ContinuousBatchingEngine, Request, ServeEngine


def main():
    cfg = reduce_config(get_config("granite-3-2b"))
    model = build_model(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)

    def make_req(i, arrival_s=0.0, new_tokens=8):
        return Request(rid=i,
                       prompt=jax.random.randint(
                           jax.random.fold_in(key, i), (16,),
                           0, cfg.vocab_size),
                       max_new_tokens=new_tokens, arrival_s=arrival_s)

    # ------------------------------------------------------------------
    # Offline: fixed batches, real CPU timing of one batch per issue
    # ------------------------------------------------------------------
    engine = ServeEngine(model, params, max_len=96, batch_size=4)
    engine.run_batch([make_req(100 + j) for j in range(4)])  # compile

    offline_sut = ServeEngineSUT(
        engine, cfg, name="granite-3-2b-offline",
        make_requests=lambda samples: [make_req(4 * s["idx"] + j)
                                       for s in samples[:1]
                                       for j in range(4)])
    offline = PowerRun(offline_sut, Offline(batch=4, min_duration_s=60.0),
                       seed=0).run()
    res = offline.outcome.result
    print(f"Offline: {res.n_queries} queries, {res.qps:.2f} samples/s, "
          f"p90 {res.p90 * 1e3:.1f} ms, "
          f"{offline.samples_per_joule:.4f} samples/J "
          f"(review {'ACCEPTED' if offline.passed else 'REJECTED'})")

    # ------------------------------------------------------------------
    # Server: Poisson arrivals -> continuous-batching admission queue.
    # Mixed token budgets make the fixed-batch straggler problem real;
    # the slot engine retires short requests early and refills.
    # ------------------------------------------------------------------
    cont = ContinuousBatchingEngine(model, params, max_len=96, n_slots=4,
                                    chunk_steps=4)
    cont.serve([make_req(200, new_tokens=4)],
               honor_arrivals=False)                  # warmup/compile
    server_sut = ContinuousBatchingSUT(
        cont, cfg, name="granite-3-2b-server",
        make_request=lambda i, s, a: make_req(
            i, arrival_s=a, new_tokens=(4, 12, 8)[i % 3]))
    run = PowerRun(server_sut,
                   Server(target_qps=res.qps * 2, latency_slo_s=10.0,
                          mode="queue", min_duration_s=0.5),
                   seed=0)
    r = run.run()
    m = r.outcome.server
    print(f"Server:  {r.outcome.result.qps:.2f} qps, "
          f"{m.tokens_per_s:.1f} tok/s, "
          f"p99 {r.outcome.result.p99 * 1e3:.1f} ms, "
          f"SLO met: {r.outcome.slo_met}")
    print(f"  TTFT p99 {m.ttft_p(99) * 1e3:.1f} ms, "
          f"TPOT mean {m.tpot_mean * 1e3:.2f} ms, "
          f"host syncs {cont.host_syncs}")
    e = np.asarray(list((r.per_request_energy_j or {}).values()))
    print(f"energy: {r.summary.energy_j:.1f} J -> "
          f"{r.samples_per_joule:.4f} samples/J, "
          f"{m.total_tokens / max(r.summary.energy_j, 1e-9):.3f} tok/J, "
          f"per-request mean {e.mean():.2f} J")
    # the meter stack's per-domain split: DC rails vs the wall boundary
    print("per-domain: " + ", ".join(
        f"{k}={v:.1f} J" for k, v in sorted(r.per_domain_energy_j.items())))
    print(r.report.render())


if __name__ == "__main__":
    main()
