"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with the FULL production stack — deterministic data pipeline, fused
AdamW, checkpoint/restart through an injected node failure, straggler
monitoring, per-node power telemetry, energy-to-train summarization and
a compliance review.

  PYTHONPATH=src python examples/train_e2e.py --steps 300
  PYTHONPATH=src python examples/train_e2e.py --steps 12 --smoke
"""
import argparse
import time

import jax
import numpy as np

from repro.checkpoint import (CheckpointManager, SimulatedFailure,
                              run_with_recovery)
from repro.configs import get_config
from repro.core import (MLPerfLogger, StepWork, SwitchEstimator,
                        SystemDescription, SystemPowerModel, review)
from repro.core.summarizer import energy_to_train
from repro.data import SyntheticTokens
from repro.hw import DATACENTER_V5E
from repro.models import build_model
from repro.train import init_train_state, make_train_step
from repro.train.train_step import TrainHParams


def model_100m():
    """~106M parameters: 10L x d640, GQA 10/5, SwiGLU 2560, vocab 32000."""
    return get_config(
        "qwen3-1.7b", n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
        d_head=64, d_ff=2560, vocab_size=32000, qk_norm=True,
        dtype="float32", remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()
    if args.smoke:
        args.steps, args.batch, args.seq = min(args.steps, 12), 4, 64

    cfg = model_100m()
    model = build_model(cfg)
    print(f"params: {cfg.param_count() / 1e6:.1f}M")
    hp = TrainHParams(total_steps=args.steps, warmup=20, peak_lr=6e-4)
    state = init_train_state(model, jax.random.PRNGKey(0), hp)
    step = jax.jit(make_train_step(model, hp))
    data = SyntheticTokens(cfg.vocab_size, args.seq, args.batch)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    # --- telemetry: 1 virtual node (this host models an 8-chip node)
    n_chips = 8
    meter = SystemPowerModel(DATACENTER_V5E, n_chips)
    tokens = args.batch * args.seq
    work = StepWork(flops=6.0 * cfg.param_count() * tokens / n_chips,
                    hbm_bytes=16.0 * cfg.param_count() / n_chips,
                    ici_bytes=2.0 * cfg.param_count() / n_chips)
    watts = meter.system_watts(work)

    perf = MLPerfLogger("perf")
    node_log = MLPerfLogger("power")
    t0 = time.monotonic()
    perf.run_start(0.0)

    fail_at = {args.steps // 3: True} if args.steps >= 9 else {}

    def injector(s):
        if fail_at.pop(s, None):
            print(f"!! injected node failure at step {s}")
            raise SimulatedFailure(s)

    losses = []
    last_sample = [0.0]

    def on_step(s, metrics):
        # out-of-band telemetry: fill a 1 Hz sample grid up to now (a
        # real BMC samples on its own clock; tying samples to step
        # completion under-samples slow steps and fails review R2/R3)
        t_ms = (time.monotonic() - t0) * 1e3
        while last_sample[0] <= t_ms:
            node_log.power_sample(last_sample[0], watts, node="node0")
            last_sample[0] += 1000.0
        losses.append(float(metrics["loss"]))
        if s % 10 == 0 or s <= 3:
            print(f"step {s:4d} loss={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f}")

    state, rep = run_with_recovery(
        state=state, step_fn=step, data_fn=data.batch, ckpt=ckpt,
        total_steps=args.steps, ckpt_every=max(5, args.steps // 10),
        failure_injector=injector, on_step=on_step)

    dur_ms = (time.monotonic() - t0) * 1e3
    perf.result("samples_processed", args.steps * args.batch, dur_ms)
    perf.run_stop(dur_ms)

    print(f"\nrecovered from {rep.failures} failure(s); "
          f"straggler events: {len(rep.straggler_events)}")
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first else 'did not decrease'})")

    est = SwitchEstimator().estimate(n_chips, dur_ms / 1e3)
    summary = energy_to_train(perf.events, {"node0": node_log.events},
                              switch_estimate=est)
    print(f"energy-to-train (modeled {n_chips}-chip node): "
          f"{summary.energy_j / 1e3:.2f} kJ over {summary.window_s:.0f} s "
          f"({summary.avg_watts:.0f} W avg)")
    rev = review(perf.events, node_log.events, SystemDescription(
        scale="datacenter", n_chips=n_chips, telemetry_accuracy=0.02,
        scope=("chips", "host", "interconnect"),
        estimated_components={"switch": est["methodology"]},
        max_system_watts=5000, idle_system_watts=500),
        min_duration_s=1.0 if args.smoke else 60.0)
    print(rev.render())
    if args.steps >= 50:            # smoke runs sit inside lr warmup
        assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
