"""Quickstart: train a small LM for a few steps *under power measurement*.

Demonstrates the public API end to end in under a minute on CPU:
  config -> model -> train loop -> MLPerf-style power log -> Samples/J.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import (MLPerfLogger, StepWork, SystemPowerModel,
                        SystemDescription, review, summarize)
from repro.data import SyntheticTokens
from repro.hw import EDGE_SYSTEM
from repro.models import build_model
from repro.train import init_train_state, make_train_step
from repro.train.train_step import TrainHParams


def main(steps: int = 10):
    cfg = reduce_config(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    hp = TrainHParams(total_steps=steps, warmup=2)
    state = init_train_state(model, jax.random.PRNGKey(0), hp)
    step = jax.jit(make_train_step(model, hp))
    data = SyntheticTokens(cfg.vocab_size, seq_len=64, global_batch=8)

    # power instrumentation: the model's per-step work drives the meter
    n_params = cfg.param_count()
    tokens = 8 * 64
    work = StepWork(flops=6.0 * n_params * tokens,
                    hbm_bytes=6.0 * n_params * 4)
    meter = SystemPowerModel(EDGE_SYSTEM, 1)
    watts = meter.system_watts(work)

    perf, power = MLPerfLogger("perf"), MLPerfLogger("power")
    t0 = time.monotonic()
    perf.run_start(0.0)
    for i in range(steps):
        state, metrics = step(state, data.batch(i))
        print(f"step {i}: loss={float(metrics['loss']):.4f} "
              f"modeled_power={watts:.1f} W")
    dur_ms = (time.monotonic() - t0) * 1e3
    perf.result("samples_processed", steps * 8, dur_ms)
    perf.run_stop(dur_ms)
    # the analyzer samples on its own clock (2 Hz), decoupled from steps
    for t_ms in np.arange(0.0, dur_ms + 1, 500.0):
        power.power_sample(float(t_ms), watts)

    s = summarize(perf.events, power.events)
    print(f"\nenergy: {s.energy_j:.1f} J over {s.window_s:.1f} s "
          f"-> {s.samples_per_joule:.4f} samples/J")
    rep = review(perf.events, power.events,
                 SystemDescription(scale="edge", max_system_watts=60,
                                   idle_system_watts=8),
                 min_duration_s=1.0)  # quickstart: relaxed duration
    print(rep.render())


if __name__ == "__main__":
    main()
