"""§Roofline: the full baseline table from the dry-run artifacts —
three terms, dominant bottleneck, MODEL_FLOPS ratio, per-device GiB."""
from __future__ import annotations

from benchmarks.common import all_cells, csv_row


def run() -> list[dict]:
    rows = []
    for rec in all_cells(""):
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "compute_s": rec["compute_s"], "memory_s": rec["memory_s"],
            "collective_s": rec["collective_s"],
            "bottleneck": rec["bottleneck"],
            "mf_ratio": rec["model_flops_ratio"],
            "gib_per_dev": (rec["arg_bytes"] + rec["temp_bytes"]) / 2**30,
            "fits_hbm": rec["fits_hbm"],
        })
    return rows


def csv() -> list[str]:
    return [csv_row(
        f"roofline[{r['arch']}|{r['shape']}|{r['mesh']}]",
        max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
        f"bneck={r['bottleneck']};mf={r['mf_ratio']:.3f};"
        f"gib={r['gib_per_dev']:.2f};fits={r['fits_hbm']}")
        for r in run()]


if __name__ == "__main__":
    for r in run():
        print(r)
