"""Scale sweep: perf-vs-watts across {1 device, TP=k, R replicas}.

The paper's core claim is that energy efficiency must be measured
*across scales*; this sweep walks the serving stack up the datacenter
end of the µW->MW axis and reports tokens/s and tokens/J at each scale
point, all through the same ``PowerRun`` methodology:

- ``tp1``   — one ``ContinuousBatchingEngine`` on one device;
- ``tpK``   — one ``ShardedContinuousBatchingEngine`` over a K-way
  tensor-parallel mesh (``ShardedSUT``: meter spans K chips);
- ``r2``    — two independent engines behind one admission queue
  (``ReplicatedSUT``: fleet power is the sum of the replica traces).

On CPU CI run under 4 virtual host devices::

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m benchmarks.scale_sweep --smoke

With a single device the TP point degrades to ``tp1`` only (the CI
sharded smoke stage supplies the virtual mesh).
"""

from __future__ import annotations

import numpy as np

SLOTS = 4
PROMPT_LEN = 12
MAX_LEN = 48
MIX = (4, 12, 6, 8)  # mixed decode budgets: stragglers + short ones
QPS = 200.0  # saturating offered load: every point runs backlogged
REPLICAS = 2


def _make_request(cfg, rid, arrival_s):
    import jax

    from repro.serving import Request

    key = jax.random.PRNGKey(11)
    return Request(
        rid=rid,
        prompt=np.asarray(
            jax.random.randint(
                jax.random.fold_in(key, rid), (PROMPT_LEN,), 0, cfg.vocab_size
            )
        ),
        max_new_tokens=MIX[rid % len(MIX)],
        arrival_s=float(arrival_s),
    )


def _warm(engine, cfg):
    engine.serve(
        [_make_request(cfg, 10**6, 0.0)], honor_arrivals=False
    )


def _run_once(sut, n_queries):
    from repro.harness import PowerRun, Server

    scenario = Server(
        target_qps=QPS,
        latency_slo_s=30.0,
        min_duration_s=0.0,
        min_queries=n_queries,
        mode="queue",
    )
    # sample every meter-stack channel at 1 kHz so the energy window
    # resolves each point's sub-second duration
    return PowerRun(sut, scenario, seed=0, sample_hz=1000.0).run()


def _measure_points(suts, n_queries):
    """Interleaved best-of per scale point (the perf gate compares
    these sub-second numbers; see benchmarks.common)."""
    from functools import partial

    from benchmarks.common import interleaved_best_of

    return interleaved_best_of(
        {name: partial(_run_once, sut, n_queries) for name, sut in suts.items()}
    )


def _finish_point(name, r, chips):
    m = r.outcome.server
    tok_j = m.total_tokens / max(r.summary.energy_j, 1e-12)
    us_per_tok = r.outcome.result.duration_s / max(1, m.total_tokens) * 1e6
    point = {
        "tokens_per_s": m.tokens_per_s,
        "tok_per_j": tok_j,
        "us_per_tok": us_per_tok,
        "avg_watts": r.summary.avg_watts,
        "chips": chips,
    }
    return (
        f"scale_{name},{us_per_tok:.1f},"
        f"{m.tokens_per_s:.1f}toks/s;{tok_j:.4f}tok/J;"
        f"{r.summary.avg_watts:.1f}W;{chips}chips"
    ), point


def _sweep(smoke: bool):
    """Run every scale point; returns ``(rows, points)``."""
    import jax

    from repro.configs import get_config, reduce_config
    from repro.harness import ContinuousBatchingSUT, ReplicatedSUT, ShardedSUT
    from repro.models import build_model
    from repro.models.param import init_params
    from repro.serving import (
        ContinuousBatchingEngine,
        ShardedContinuousBatchingEngine,
    )

    n_dev = len(jax.devices())
    if smoke and n_dev == 1:
        # the tier-1 gate's dedicated sharded-smoke stage runs this
        # sweep on a 4-device virtual mesh; don't pay for the degraded
        # single-device points twice per gate run
        return [
            "scale_sweep_skipped,0.0,single-device-smoke;covered-by-"
            "sharded-smoke-stage (XLA_FLAGS="
            "--xla_force_host_platform_device_count=4)"
        ], {}

    cfg = reduce_config(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    # enough queries that every point (incl. the threaded replica
    # fleet, which splits the queue) decodes long enough to dominate
    # admission overhead — the perf gate needs stable numbers
    n = 16 if smoke else 24

    def make_request(i, s, a):
        from repro.core.loadgen import qid_of

        # rid from the loadgen query id: replicas each see a share of
        # the queue and attribution needs fleet-unique ids
        return _make_request(cfg, qid_of(s, i), a)

    rows = []
    points: dict = {}
    suts: dict = {}
    chips: dict = {}

    # -- 1 device ------------------------------------------------------
    eng1 = ContinuousBatchingEngine(
        model, params, max_len=MAX_LEN, n_slots=SLOTS, chunk_steps=4
    )
    _warm(eng1, cfg)
    suts["tp1"] = ContinuousBatchingSUT(
        eng1, cfg, name="scale-tp1", make_request=make_request
    )
    chips["tp1"] = 1

    # -- tensor parallel over every available device -------------------
    tp_skipped = None
    if n_dev > 1:
        eng_tp = ShardedContinuousBatchingEngine(
            model, params, tp=n_dev, max_len=MAX_LEN, n_slots=SLOTS,
            chunk_steps=4,
        )
        _warm(eng_tp, cfg)
        suts[f"tp{n_dev}"] = ShardedSUT(
            eng_tp, cfg, name=f"scale-tp{n_dev}", make_request=make_request
        )
        chips[f"tp{n_dev}"] = n_dev
    else:
        tp_skipped = (
            "scale_tp_skipped,0.0,single-device;set XLA_FLAGS="
            "--xla_force_host_platform_device_count=4"
        )

    # -- replica fleet -------------------------------------------------
    reps = []
    for _ in range(REPLICAS):
        eng = ContinuousBatchingEngine(
            model, params, max_len=MAX_LEN, n_slots=SLOTS, chunk_steps=4
        )
        _warm(eng, cfg)
        reps.append(
            ContinuousBatchingSUT(
                eng, cfg, name="scale-replica", make_request=make_request
            )
        )
    suts[f"r{REPLICAS}"] = ReplicatedSUT(reps, name=f"scale-r{REPLICAS}")
    chips[f"r{REPLICAS}"] = REPLICAS

    best = _measure_points(suts, n)
    for name in suts:
        row, points[name] = _finish_point(name, best[name], chips[name])
        rows.append(row)
        if name == "tp1" and tp_skipped is not None:
            rows.append(tp_skipped)

    base_tps = points["tp1"]["tokens_per_s"]
    fleet_tps = points[f"r{REPLICAS}"]["tokens_per_s"]
    points[f"r{REPLICAS}"]["speedup"] = fleet_tps / max(base_tps, 1e-9)
    rows.append(
        f"scale_r{REPLICAS}_speedup,0.0,{fleet_tps / max(base_tps, 1e-9):.2f}x"
    )
    return rows, points


def metrics(smoke: bool = False) -> dict:
    """Scale-point numbers keyed for the CI perf gate
    (``scripts/perf_gate.py``)."""
    _, points = _sweep(smoke)
    return points


def csv(smoke: bool = False) -> list[str]:
    rows, _ = _sweep(smoke)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for r in csv(smoke=args.smoke):
        print(r)
