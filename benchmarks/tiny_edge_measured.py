"""REAL CPU measurements: tiny single-stream and edge offline runs.

Wall-clock µs/call measured on this host's CPU (the only real silicon
available), each driven end to end through the public harness:
``PowerRun(sut, scenario).run()`` = loadgen -> Director + virtual
analyzer -> summarizer -> compliance review, in one call.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_config, reduce_config
from repro.core import SystemPowerModel
from repro.core.compliance import SystemDescription
from repro.harness import (CallableSUT, MultiStream, Offline, PowerRun,
                           SingleStream, TinySUT, constant_power,
                           throughput_watts)
from repro.hw import EDGE_SYSTEM
from repro.models import build_model, tiny as tiny_mod
from repro.models.param import init_params


def tiny_single_stream() -> dict:
    """Real tiny-KWS forward latency + duty-cycled µW energy, measured
    through the harness at a reduced duration (10 Hz detector frames —
    a faster cadence than the example's 4 Hz so 200 queries fit a short
    benchmark window; per-inference energy includes the per-period
    sleep floor, so it is not directly comparable across periods)."""
    cfg = get_config("tiny-kws")
    model = tiny_mod.TinyModel(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    fwd = jax.jit(lambda p, x: model(p, x))
    x = jnp.ones((1, tiny_mod.IN_T, tiny_mod.IN_F))
    fwd(params, x).block_until_ready()          # compile

    period = 0.1
    sut = TinySUT(lambda: fwd(params, x).block_until_ready(),
                  macs=tiny_mod.macs(cfg),
                  sram_bytes=tiny_mod.sram_bytes(cfg),
                  period_s=period, name="tiny-kws")
    scenario = SingleStream(min_duration_s=2.0, min_queries=200)
    r = PowerRun(sut, scenario, seed=0).run()
    lat = np.asarray(sut.real_latencies_s)
    e_inf = r.summary.energy_j / r.outcome.result.n_queries
    return {
        "name": "tiny_kws_single_stream",
        "us_per_call": float(np.mean(lat) * 1e6),
        "p90_us": float(np.percentile(lat, 90) * 1e6),
        "measured_uj_per_inf": e_inf * 1e6,
        "inv_joules": 1.0 / e_inf,
        "review_passed": r.passed,
    }


def edge_offline() -> dict:
    """Edge ViT training-loss step under the Offline scenario; analytic
    edge-system watts shaped by the measured throughput."""
    cfg = reduce_config(get_config("edge-vit"))
    model = build_model(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    b = 8
    tok = jnp.zeros((b, 8), jnp.int32)
    pe = jnp.ones((b, cfg.vlm.n_patches, cfg.d_model), jnp.float32)
    loss_fn = jax.jit(lambda p: model.train_loss(
        p, {"tokens": tok, "labels": tok, "patch_embeds": pe})[0])
    loss_fn(params).block_until_ready()
    meter = SystemPowerModel(EDGE_SYSTEM, 1)

    def issue_batch(samples):
        t0 = time.perf_counter()
        loss_fn(params).block_until_ready()
        return time.perf_counter() - t0

    def power_factory(outcome):
        return constant_power(
            throughput_watts(meter, cfg, outcome.result.qps))

    sut = CallableSUT(name="edge-vit", issue_batch=issue_batch,
                      power_factory=power_factory)
    r = PowerRun(sut, Offline(batch=b, min_duration_s=1.0), seed=0).run()
    res = r.outcome.result
    return {
        "name": "edge_vit_offline",
        "us_per_call": float(res.duration_s / max(1, res.n_queries // b)
                             * 1e6),
        "samples_per_s": res.qps,
        "samples_per_joule": r.samples_per_joule,
        "review_passed": r.passed,
    }


def edge_multi_stream() -> dict:
    """MultiStream bursts (edge rules): 8-sample bursts on the tiny
    model, p99 per-burst latency through the harness."""
    cfg = get_config("tiny-kws")
    model = tiny_mod.TinyModel(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    n = 8
    fwd = jax.jit(lambda p, x: model(p, x))
    xb = jnp.ones((n, tiny_mod.IN_T, tiny_mod.IN_F))
    fwd(params, xb).block_until_ready()         # compile

    def issue_burst(samples):
        t0 = time.perf_counter()
        fwd(params, xb).block_until_ready()
        return time.perf_counter() - t0

    sut = CallableSUT(name="tiny-kws-burst", issue_batch=issue_burst,
                      power=1.0,
                      sysdesc=SystemDescription(
                          scale="edge", max_system_watts=60,
                          idle_system_watts=0.5))
    r = PowerRun(sut, MultiStream(n_streams=n, min_duration_s=0.5,
                                  min_queries=64), seed=0).run()
    res = r.outcome.result
    return {
        "name": "edge_multi_stream",
        "us_per_call": float(res.p99 * 1e6),
        "p99_burst_ms": res.p99 * 1e3,
        "samples_per_s": res.qps,
        "review_passed": r.passed,
    }


def full_pipeline_compliance() -> dict:
    """End-to-end: synthetic edge run through the one-call harness."""
    sut = CallableSUT(name="edge-synthetic", issue=lambda s: 0.01,
                      power=42.0,
                      sysdesc=SystemDescription(
                          scale="edge", max_system_watts=60,
                          idle_system_watts=8))
    r = PowerRun(sut, SingleStream(min_duration_s=66.0), seed=0).run()
    return {"name": "edge_pipeline_compliance",
            "samples_per_joule": r.samples_per_joule,
            "review_passed": r.passed}


def run() -> list[dict]:
    return [tiny_single_stream(), edge_offline(), edge_multi_stream(),
            full_pipeline_compliance()]


def csv() -> list[str]:
    out = []
    for r in run():
        us = r.get("us_per_call", 0.0)
        rest = ";".join(f"{k}={v}" for k, v in r.items()
                        if k not in ("name", "us_per_call"))
        out.append(csv_row(r["name"], us, rest))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
