"""REAL CPU measurements: tiny single-stream and edge offline runs.

Wall-clock µs/call measured on this host's CPU (the only real silicon
available), paired with the methodology pipeline end to end: loadgen ->
virtual analyzer / IO manager -> summarizer -> compliance review.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_config, reduce_config
from repro.core import (Clock, IOManager, MLPerfLogger, QuerySampleLibrary,
                        SystemDescription, TinyPowerModel, review,
                        run_single_stream, summarize)
from repro.models import build_model, tiny as tiny_mod
from repro.models.param import init_params


def tiny_single_stream() -> dict:
    cfg = get_config("tiny-kws")
    model = tiny_mod.TinyModel(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    fwd = jax.jit(lambda p, x: model(p, x))
    x = jnp.ones((1, tiny_mod.IN_T, tiny_mod.IN_F))
    fwd(params, x).block_until_ready()          # compile

    lat = []

    def issue(sample):
        t0 = time.perf_counter()
        fwd(params, x).block_until_ready()
        dt = time.perf_counter() - t0
        lat.append(dt)
        return dt

    qsl = QuerySampleLibrary(64, lambda i: {"idx": i})
    res = run_single_stream(issue, qsl, clock=Clock(), min_queries=200)

    # methodology pipeline on the modeled waveform
    tm = TinyPowerModel()
    macs, sram = tiny_mod.macs(cfg), tiny_mod.sram_bytes(cfg)
    t, amps, pin = tm.waveform(macs, sram, n_inferences=16, period_s=0.1)
    e_inf, n = IOManager().energy_per_inference(t, amps, pin)
    return {
        "name": "tiny_kws_single_stream",
        "us_per_call": float(np.mean(lat) * 1e6),
        "p90_us": res.percentile(90) * 1e6,
        "modeled_mj_per_inf": e_inf * 1e3,
        "inv_joules": 1.0 / e_inf,
        "windows": n,
    }


def edge_offline() -> dict:
    cfg = reduce_config(get_config("edge-vit"))
    model = build_model(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    b = 8
    tok = jnp.zeros((b, 8), jnp.int32)
    pe = jnp.ones((b, cfg.vlm.n_patches, cfg.d_model), jnp.float32)
    loss_fn = jax.jit(lambda p: model.train_loss(
        p, {"tokens": tok, "labels": tok, "patch_embeds": pe})[0])
    loss_fn(params).block_until_ready()
    times = []
    for _ in range(10):
        t0 = time.perf_counter()
        loss_fn(params).block_until_ready()
        times.append(time.perf_counter() - t0)
    return {
        "name": "edge_vit_offline",
        "us_per_call": float(np.mean(times) * 1e6),
        "samples_per_s": b / float(np.mean(times)),
    }


def full_pipeline_compliance() -> dict:
    """End-to-end: synthetic edge run through log->summarize->review."""
    perf = MLPerfLogger("perf")
    perf.run_start(0.0)
    perf.result("samples_processed", 6600, 66_000.0)
    perf.run_stop(66_000.0)
    power = MLPerfLogger("power")
    rng = np.random.default_rng(0)
    for i in range(661):
        power.power_sample(i * 100.0, 42.0 + rng.normal(0, 0.5))
    s = summarize(perf.events, power.events)
    rep = review(perf.events, power.events, SystemDescription(
        scale="edge", max_system_watts=60, idle_system_watts=8))
    return {"name": "edge_pipeline_compliance",
            "samples_per_joule": s.samples_per_joule,
            "review_passed": rep.passed}


def run() -> list[dict]:
    return [tiny_single_stream(), edge_offline(),
            full_pipeline_compliance()]


def csv() -> list[str]:
    out = []
    for r in run():
        us = r.get("us_per_call", 0.0)
        rest = ";".join(f"{k}={v}" for k, v in r.items()
                        if k not in ("name", "us_per_call"))
        out.append(csv_row(r["name"], us, rest))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
