"""Fig. 8: quantization-driven efficiency at fixed accuracy targets.

Measures, per arch, normalized Samples/J for the bf16, fp16, fp8 and
int8 deployments of the same inference cell — the FP8-closes-the-gap
story of Fig. 8 — plus a REAL accuracy measurement on the tiny model
(int8 vs fp32 logits agreement on CPU, the 99%/99.9% target premise).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, load_cell
from repro.core.power_model import StepWork, SystemPowerModel, roofline
from repro.hw import DATACENTER_V5E

ARCHS = ["yi-9b", "qwen3-1.7b", "granite-3-2b"]
# effective precisions: (flops path, bytes scale) vs bf16 baseline
PRECISIONS = {
    "bf16": dict(int8=False, byte_scale=1.0, flop_scale=1.0),
    "fp16": dict(int8=False, byte_scale=1.0, flop_scale=1.0),
    "fp8": dict(int8=True, byte_scale=0.5, flop_scale=1.0),
    "int8": dict(int8=True, byte_scale=0.5, flop_scale=1.0),
}
# which precision meets which accuracy target (paper's Fig. 8 narrative)
TARGET_99 = "int8"
TARGET_999_OLD = "fp16"       # pre-v3.1 submissions
TARGET_999_NEW = "fp8"        # v3.1+ hardware


def _eff(rec, precision: str) -> float:
    p = PRECISIONS[precision]
    base = StepWork(rec["flops"], rec["hbm_bytes"], rec["coll_bytes"])
    work = StepWork(base.flops * p["flop_scale"],
                    base.hbm_bytes * p["byte_scale"],
                    base.ici_bytes * p["byte_scale"],
                    flops_int8=base.flops if p["int8"] else 0.0)
    model = SystemPowerModel(DATACENTER_V5E, rec["n_devices"])
    rt = roofline(work, DATACENTER_V5E.chip)
    watts = model.system_watts(work, rt.step_s)
    return 1.0 / (watts * rt.step_s)          # samples/J up to const


def run() -> list[dict]:
    rows = []
    for arch in ARCHS:
        rec = load_cell(arch, "prefill_32k", "pod")
        if rec is None:
            continue
        base = _eff(rec, TARGET_99)
        rows.append({
            "arch": arch,
            "eff_99_int8": 1.0,
            "eff_999_fp16": _eff(rec, TARGET_999_OLD) / base,
            "eff_999_fp8": _eff(rec, TARGET_999_NEW) / base,
        })
    rows.append(tiny_accuracy_measurement())
    return rows


def tiny_accuracy_measurement() -> dict:
    """REAL CPU measurement: int8-quantized tiny model vs fp32."""
    from repro.configs import get_config
    from repro.kernels.int8_matmul import int8_matmul, quantize_int8
    from repro.models.param import init_params
    from repro.models.tiny import IN_F, IN_T, TinyModel

    cfg = get_config("tiny-kws")
    model = TinyModel(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (256, IN_T, IN_F))
    ref = model(params, x)
    # int8 path on the dominant pointwise convs (pw matmuls)
    h = x @ params["stem"]
    for i in range(cfg.n_layers):
        w = params[f"dw{i}"]
        hp = jnp.pad(h, ((0, 0), (1, 1), (0, 0)))
        conv = sum(hp[:, j:j + h.shape[1]] * w[j] for j in range(3))
        cq, sx = quantize_int8(conv.reshape(-1, conv.shape[-1]), axis=1)
        wq, sw = quantize_int8(params[f"pw{i}"], axis=0)
        out = int8_matmul(cq, wq, sx, sw, out_dtype=jnp.float32,
                          interpret=True)
        h = jax.nn.relu(out.reshape(conv.shape) + params[f"b{i}"])
    q_logits = h.mean(axis=1) @ params["head"]
    agree = float(jnp.mean(jnp.argmax(q_logits, -1) == jnp.argmax(ref, -1)))
    rel = float(jnp.linalg.norm(q_logits - ref) / jnp.linalg.norm(ref))
    return {"arch": "tiny-kws(real)", "int8_argmax_agreement": agree,
            "int8_rel_err": rel}


def csv() -> list[str]:
    out = []
    for r in run():
        if "eff_999_fp8" in r:
            out.append(csv_row(
                f"fig8_quant[{r['arch']}]", 0.0,
                f"eff99_int8=1.0;eff999_fp16={r['eff_999_fp16']:.3f};"
                f"eff999_fp8={r['eff_999_fp8']:.3f}"))
        else:
            out.append(csv_row(
                "fig8_quant[tiny_accuracy]", 0.0,
                f"agree={r['int8_argmax_agreement']:.4f};"
                f"rel={r['int8_rel_err']:.4f}"))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
