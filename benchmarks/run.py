# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows aggregated from every benchmark module.
#
#   python -m benchmarks.run            full sweep
#   python -m benchmarks.run --smoke    reduced sizes (CI tier-1 gate)
#
# Modules whose ``csv`` accepts a ``smoke`` keyword scale themselves
# down under --smoke; the analytic ones run at full size either way.
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for the CI gate")
    args = ap.parse_args(argv)

    from benchmarks import (accuracy_cost, efficiency_trends,
                            energy_per_inference, fleet_sweep,
                            power_breakdown, power_range, prefix_cache,
                            quantization_efficiency, resilience,
                            roofline_table, scale_sweep, scaling_energy,
                            serving_throughput, slo_sweep,
                            speculative_efficiency, sw_hw_optimizations,
                            tiny_edge_measured)

    modules = [
        ("fig2_power_range", power_range),
        ("fig4_efficiency_trends", efficiency_trends),
        ("fig5_scaling_energy", scaling_energy),
        ("fig6_energy_per_inference", energy_per_inference),
        ("fig7_accuracy_cost", accuracy_cost),
        ("fig8_quantization", quantization_efficiency),
        ("fig9_10_sw_hw", sw_hw_optimizations),
        ("roofline_table", roofline_table),
        ("measured_tiny_edge", tiny_edge_measured),
        ("serving_throughput", serving_throughput),
        ("scale_sweep", scale_sweep),
        ("speculative_efficiency", speculative_efficiency),
        ("power_breakdown", power_breakdown),
        ("resilience", resilience),
        ("prefix_cache", prefix_cache),
        ("slo_sweep", slo_sweep),
        ("fleet_sweep", fleet_sweep),
    ]
    print("name,us_per_call,derived")
    n_rows = 0
    n_error = 0
    timings = []
    for name, mod in modules:
        t0 = time.perf_counter()
        try:
            kw = {}
            if args.smoke and \
                    "smoke" in inspect.signature(mod.csv).parameters:
                kw["smoke"] = True
            rows = list(mod.csv(**kw))
        except Exception as e:  # noqa: BLE001 — report all benches
            # which exception class fired goes into the derived column
            # (CSV stays 3 columns); the traceback goes to stderr
            rows = [f"{name},0.0,ERROR:{type(e).__name__}"]
            traceback.print_exc(file=sys.stderr)
        timings.append((name, time.perf_counter() - t0))
        for row in rows:
            print(row)
            n_rows += 1
            # a module may also *emit* ERROR rows instead of raising;
            # both forms must fail the gate, not just the exceptions
            if row.split(",", 2)[-1].startswith("ERROR"):
                n_error += 1
    # per-module wall time: how the sweep budget is actually spent
    # (comment rows, so CSV parsers and the perf gate skip them)
    for name, dt in timings:
        print(f"# elapsed: {name} {dt:.1f}s")
    total_s = sum(dt for _, dt in timings)
    print(f"# summary: {n_rows} rows, {n_error} ERROR, {total_s:.1f}s")
    if n_error:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
