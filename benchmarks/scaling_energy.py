"""Fig. 5: time-to-train and energy-to-train vs accelerator count.

Reproduces the paper's core scaling result: with more chips, absolute
time-to-train falls with diminishing returns (collective share grows,
per-chip utilization falls) while energy-to-train RISES (more
accelerator-hours + interconnect/switch energy).

Data points come from scaling dry-runs (experiments/scaling/*.json,
produced by ``python -m benchmarks.scaling_energy --compile``) — the
same lower+compile+calibrate pipeline as the production dry-run, at
data-parallel widths 32..512 chips.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import csv_row, work_from_cell
from repro.core.power_model import SystemPowerModel, roofline
from repro.hw import DATACENTER_V5E

SCALE_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "scaling")
ARCH = "qwen3-1.7b"
TOKEN_BUDGET = 50e9                     # tokens to "train" the model
MESHES = [(4, 16), (16, 16), (32, 16)]  # 64/256/512 chips


def compile_points():
    """Compile the scaling cells (needs the 512-device env)."""
    from repro.configs import SHAPES, get_config
    from repro.launch.calibrate import calibrated_costs
    from repro.launch.mesh import make_mesh
    from repro.launch.roofline import analyze, apply_calibration
    from repro.launch.specs import build_cell

    os.makedirs(SCALE_DIR, exist_ok=True)
    cfg = get_config(ARCH)
    shape = SHAPES["train_4k"]
    for dp, tp in MESHES:
        n = dp * tp
        path = os.path.join(SCALE_DIR, f"{ARCH}__{n}.json")
        if os.path.exists(path):
            print(f"cached {n}")
            continue
        axes = (("data", "model") if n <= 256 else ("pod", "data", "model"))
        shp = (dp, tp) if n <= 256 else (2, dp // 2, tp)
        mesh = make_mesh(shp, axes)
        cell = build_cell(cfg, shape, mesh)
        compiled = cell.lower().compile()
        rep = analyze(cell, compiled, mesh_name=f"{n}chips")
        rep = apply_calibration(rep, calibrated_costs(cfg, shape, mesh))
        with open(path, "w") as f:
            json.dump(rep.to_json(), f, indent=1)
        print(f"compiled {n} chips: bottleneck={rep.bottleneck}")


def run() -> list[dict]:
    from repro.configs import SHAPES

    shape = SHAPES["train_4k"]
    tokens_per_step = shape.global_batch * shape.seq_len
    steps = TOKEN_BUDGET / tokens_per_step
    rows = []
    if not os.path.isdir(SCALE_DIR):
        return rows
    for fn in sorted(os.listdir(SCALE_DIR),
                     key=lambda x: int(x.split("__")[1].split(".")[0])):
        with open(os.path.join(SCALE_DIR, fn)) as f:
            rec = json.load(f)
        n = rec["n_devices"]
        model = SystemPowerModel(DATACENTER_V5E, n)
        work = work_from_cell(rec)
        rt = roofline(work, DATACENTER_V5E.chip)
        step_s = rt.step_s
        watts = model.system_watts(work, step_s)
        rows.append({
            "n_chips": n,
            "step_s": step_s,
            "time_to_train_h": steps * step_s / 3600.0,
            "energy_to_train_kwh": steps * watts * step_s / 3.6e6,
            "avg_watts": watts,
            "collective_share": rt.collective_s / max(step_s, 1e-12),
            "chip_hours": n * steps * step_s / 3600.0,
            "bottleneck": rt.bottleneck,
        })
    return rows


def csv() -> list[str]:
    out = []
    for r in run():
        out.append(csv_row(
            f"fig5_scaling[{r['n_chips']}chips]", r["step_s"] * 1e6,
            f"ttt_h={r['time_to_train_h']:.4g};"
            f"energy_kwh={r['energy_to_train_kwh']:.5g};"
            f"coll_share={r['collective_share']:.3f}"))
    return out


if __name__ == "__main__":
    import sys
    if "--compile" in sys.argv:
        compile_points()
    for r in run():
        print(r)
