"""Fig. 6: energy per inference across workloads (mJ -> hundreds of J).

Per assigned architecture: J/sample for the offline prefill cell and
J/token for decode, plus the tiny workload — reproducing the paper's
5-orders-of-magnitude span between tiny CV and datacenter LLMs."""
from __future__ import annotations

from benchmarks.common import (cell_energy, csv_row, load_cell,
                               samples_per_step)
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.power_model import TinyPowerModel
from repro.models import tiny as tiny_mod


def run() -> list[dict]:
    rows = []
    tm = TinyPowerModel()
    cfg = get_config("tiny-kws")
    e = tm.inference_energy(tiny_mod.macs(cfg), tiny_mod.sram_bytes(cfg))
    rows.append({"workload": "tiny-kws", "kind": "tiny-inference",
                 "j_per_sample": e, "n_chips": 1})
    for arch in ASSIGNED_ARCHS:
        rec = load_cell(arch, "prefill_32k", "pod")
        if rec:
            ce = cell_energy(rec)
            rows.append({"workload": arch, "kind": "prefill(32k)/sample",
                         "j_per_sample": ce["energy_j"]
                         / samples_per_step(rec),
                         "n_chips": ce["n_chips"]})
        rec = load_cell(arch, "decode_32k", "pod") or \
            load_cell(arch, "long_500k", "pod")
        if rec:
            ce = cell_energy(rec)
            rows.append({"workload": arch, "kind": "decode/token",
                         "j_per_sample": ce["energy_j"]
                         / samples_per_step(rec),
                         "n_chips": ce["n_chips"]})
    return rows


def csv() -> list[str]:
    return [csv_row(f"fig6_energy_per_inf[{r['workload']}|{r['kind']}]",
                    0.0, f"j_per_sample={r['j_per_sample']:.6g}")
            for r in run()]


if __name__ == "__main__":
    for r in run():
        print(f"{r['workload']:<20} {r['kind']:<22} "
              f"{r['j_per_sample']:>12.6g} J")
