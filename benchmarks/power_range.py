"""Fig. 2: power consumption range across MLPerf categories (µW -> MW).

Reproduces the paper's headline span: tiny systems at µW average power
(duty-cycled mW peaks), edge at watts, datacenter inference at kW, and
training pods at hundreds of kW."""
from __future__ import annotations

from benchmarks.common import cell_energy, csv_row, load_cell
from repro.configs import get_config
from repro.core.power_model import (StepWork, SystemPowerModel,
                                    TinyPowerModel)
from repro.hw import EDGE_SYSTEM
from repro.models import tiny as tiny_mod


def run() -> list[dict]:
    rows = []
    # --- tiny: duty-cycled keyword spotting
    tm = TinyPowerModel()
    cfg = get_config("tiny-kws")
    macs, sram = tiny_mod.macs(cfg), tiny_mod.sram_bytes(cfg)
    period = 1.0                               # 1 inference/s detector
    e = tm.inference_energy(macs, sram)
    avg_w = e / period + tm.device.sleep_watts
    rows.append({"category": "tiny (avg, duty-cycled)", "watts": avg_w,
                 "note": f"{e * 1e3:.3f} mJ/inf @ {period}s period"})
    rows.append({"category": "tiny (active peak)",
                 "watts": e / tm.inference_time(macs), "note": "during inf"})
    # --- edge: single SoC running edge-vit offline
    edge = SystemPowerModel(EDGE_SYSTEM, 1)
    ecfg = get_config("edge-vit")
    n = ecfg.param_count()
    w = StepWork(flops=2.0 * n * 197, hbm_bytes=2.0 * n)   # 1 img batch
    rows.append({"category": "edge (ViT-S inference)",
                 "watts": edge.system_watts(w), "note": "single SoC"})
    # --- datacenter inference (one pod row of 16 chips serving)
    rec = load_cell("yi-9b", "decode_32k", "pod")
    if rec:
        ce = cell_energy(rec)
        rows.append({"category": "datacenter inference (256 chips)",
                     "watts": ce["watts"], "note": rec["arch"]})
    # --- datacenter training single pod + multipod
    for mesh, label in (("pod", "training pod (256 chips)"),
                        ("multipod", "training 2 pods (512 chips)")):
        rec = load_cell("deepseek-v3-671b", "train_4k", mesh) or \
            load_cell("yi-9b", "train_4k", mesh)
        if rec:
            ce = cell_energy(rec)
            rows.append({"category": label, "watts": ce["watts"],
                         "note": rec["arch"]})
    # --- extrapolated flagship scale (paper: ~10 MW training est.)
    if rows and rec:
        per_chip = rows[-1]["watts"] / 512
        rows.append({"category": "extrapolated 32k-chip training",
                     "watts": per_chip * 32768, "note": "paper's MW regime"})
    return rows


def csv() -> list[str]:
    out = []
    for r in run():
        out.append(csv_row(f"fig2_power_range[{r['category']}]", 0.0,
                           f"watts={r['watts']:.6g}"))
    return out


if __name__ == "__main__":
    for r in run():
        print(f"{r['category']:<38} {r['watts']:>14.6g} W   {r['note']}")
