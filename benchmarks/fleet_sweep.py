"""24 h fleet Pareto sweep: SLO attainment vs J/token vs provisioned W.

The paper's tables fix the system; this sweep fixes the *day* — a
seeded diurnal arrival trace (24 h compressed onto the test window,
arrival count conserved) — and walks the provisioning strategies a
fleet operator actually chooses between:

- **static_min**    — 2 always-warm replicas: cheap to provision,
  backlog piles up through the midday peak (the under-provisioned
  Pareto anchor);
- **static_max**    — all 4 replicas always warm: best tails money can
  buy, but the overnight trough bills 4 idle floors (the
  over-provisioned anchor);
- **autoscaled**    — target-utilization controller with hysteresis
  scales 1..4 replicas across the day, paying modeled cold-start
  energy on every wake;
- **autoscaled_capped** — autoscaling plus a per-replica DVFS power
  cap: superlinear power-vs-frequency means the capped fleet trades a
  little headroom for a better J/token;
- **autoscaled_crash**  — the autoscaled fleet with a ``ReplicaCrash``
  mid-peak (controller re-scales around the corpse; informational);
- **hetero_carbon** — a heterogeneous fleet (tp1 / tp4 / speculative
  operating points) with carbon-aware routing against a diurnal
  gCO2/kWh grid trace, reporting emitted grams.

Every quantity is expressed in units of the *measured* warm decode
token time of a real ``ContinuousBatchingEngine`` (min of 3), so the
collision geometry — which arrivals queue behind which cold starts —
is machine-speed invariant while the reported rates track machine
speed: the perf gate normalizes the ``fleet`` group by
``fleet.calibration.tokens_per_s`` exactly like the serving groups.

Acceptance (hard asserts, also perf-gated via
``autoscaled.speedup``): the autoscaled fleet beats static max-N on
fleet J/token at equal-or-better TTFT tail-SLO attainment, the capped
replicas never exceed the cap, and per-replica energy (idle +
cold-start joules included) sums exactly to the pdu fleet total
(compliance R11).
"""
from __future__ import annotations

import time

import numpy as np

OUT_TOKENS = 16                # decoded tokens per request
SLOTS = 4                      # decode slots per baseline replica
PREFILL_TOKS = 20.0            # prefill cost in token-times
# one request occupies a slot for prefill + (n-1) slot-cadence tokens
T_REQ_TOKS = PREFILL_TOKS + (OUT_TOKENS - 1) * SLOTS
HORIZON_UNITS = 180.0          # virtual day length, in request-times
PEAK_RPU = 10.0                # midday arrivals per request-time
TROUGH_RPU = 1.0               # overnight arrivals per request-time
N_REPLICAS = 4
IDLE_W, BUSY_W = 90.0, 260.0
COLD_START_UNITS = 1.5         # spin-up, in request-times
COLD_START_W = 180.0
CAP_W = 200.0                  # DVFS cap for the capped config
TTFT_SLO_UNITS = 2.0           # TTFT SLO, in request-times
TPOT_SLO_TOKS = 6.0            # TPOT SLO, in token-times
LATENCY_SLO_UNITS = 8.0        # loose end-to-end p99 bound
TARGET_UTIL = 0.55
CONTROL_UNITS = 0.5            # controller tick, in request-times
COOLDOWN_DOWN_UNITS = 10.0
DOWN_TICKS = 3
CRASH_AT_UNITS = 95.0          # mid-peak (peak is mid-day = unit 90)
SEED = 0
DAY_S = 86_400.0


def _measure_t_tok(smoke: bool) -> float:
    """Warm decode seconds per token of a real continuous-batching
    engine at full occupancy (min of 3) — the calibration unit every
    fleet rate and SLO is expressed in."""
    import jax

    from repro.configs import get_config, reduce_config
    from repro.models import build_model
    from repro.models.param import init_params
    from repro.serving import ContinuousBatchingEngine, Request

    cfg = reduce_config(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    engine = ContinuousBatchingEngine(model, params, max_len=48,
                                      n_slots=SLOTS, chunk_steps=4)

    def batch(j):
        rng = np.random.default_rng(7_000 + j)
        return [Request(rid=10 ** 6 + 10 * j + k,
                        prompt=rng.integers(0, cfg.vocab_size, 8),
                        max_new_tokens=OUT_TOKENS)
                for k in range(SLOTS)]

    engine.serve(batch(0), honor_arrivals=False)      # compile warmup
    ts = []
    for j in range(1, 4):
        t0 = time.perf_counter()
        engine.serve(batch(j), honor_arrivals=False)
        ts.append(time.perf_counter() - t0)
    return float(min(ts)) / (SLOTS * OUT_TOKENS)


def _specs(rate_tokens_per_s: float, t_tok_s: float):
    """The homogeneous 4-replica fleet, calibrated to machine speed."""
    from repro.fleet import ReplicaSpec

    unit_s = T_REQ_TOKS * t_tok_s
    return [ReplicaSpec(label=f"tp1-{i}",
                        tokens_per_s=rate_tokens_per_s,
                        prefill_s=PREFILL_TOKS * t_tok_s,
                        n_slots=SLOTS, idle_w=IDLE_W, busy_w=BUSY_W,
                        cold_start_s=COLD_START_UNITS * unit_s,
                        cold_start_w=COLD_START_W)
            for i in range(N_REPLICAS)]


def _hetero_specs(rate_tokens_per_s: float, t_tok_s: float):
    """tp1 / tp4 / speculative operating points: same model, different
    watts-per-token — the router's choice is what the config measures."""
    from repro.fleet import ReplicaSpec

    unit_s = T_REQ_TOKS * t_tok_s
    base = dict(prefill_s=PREFILL_TOKS * t_tok_s,
                cold_start_s=COLD_START_UNITS * unit_s,
                cold_start_w=COLD_START_W)
    r = rate_tokens_per_s
    return [
        ReplicaSpec(label="tp1-a", tokens_per_s=r, n_slots=SLOTS,
                    idle_w=IDLE_W, busy_w=BUSY_W, tp=1, **base),
        ReplicaSpec(label="tp1-b", tokens_per_s=r, n_slots=SLOTS,
                    idle_w=IDLE_W, busy_w=BUSY_W, tp=1, **base),
        # tp4: 3.6x the rate for ~2.9x the dynamic draw — the
        # efficient big box (145 vs 170 mJ/token per unit rate)
        ReplicaSpec(label="tp4", tokens_per_s=3.6 * r,
                    n_slots=2 * SLOTS, idle_w=300.0, busy_w=820.0,
                    tp=4, cold_start_w=500.0,
                    prefill_s=PREFILL_TOKS * t_tok_s / 2.0,
                    cold_start_s=COLD_START_UNITS * unit_s),
        # speculative decode: 1.8x rate at modest extra draw — the
        # cheapest marginal tokens in the fleet
        ReplicaSpec(label="spec", tokens_per_s=1.8 * r, n_slots=SLOTS,
                    idle_w=100.0, busy_w=300.0, tp=1, **base),
    ]


def _trace(smoke: bool, unit_s: float):
    """The diurnal day: generated over real 24 h seconds (two days in
    full mode) at machine-independent rates, then compressed onto the
    calibrated test window — arrival count conserved exactly."""
    from repro.fleet import diurnal_trace

    n_days = 1 if smoke else 2
    units_per_day_s = HORIZON_UNITS / DAY_S
    tr = diurnal_trace(peak_qps=PEAK_RPU * units_per_day_s,
                       trough_qps=TROUGH_RPU * units_per_day_s,
                       horizon_s=n_days * DAY_S, period_s=DAY_S,
                       seed=SEED)
    return tr.compress(DAY_S / (HORIZON_UNITS * unit_s))


def _run_config(sut, trace, unit_s: float, t_tok_s: float,
                fault_plan=None) -> dict:
    """One PowerRun over the trace; returns the config's metric row."""
    from repro.core.loadgen import QuerySampleLibrary
    from repro.harness.power_run import PowerRun
    from repro.harness.scenarios import TraceServer

    qsl = QuerySampleLibrary(
        4096, lambda i: {"index": i, "out_tokens": OUT_TOKENS})
    scn = TraceServer(trace=trace,
                      latency_slo_s=LATENCY_SLO_UNITS * unit_s,
                      ttft_slo_s=TTFT_SLO_UNITS * unit_s,
                      tpot_slo_s=TPOT_SLO_TOKS * t_tok_s,
                      fault_plan=fault_plan)
    sample_hz = max(8192.0 / (trace.horizon_s * 1.5), 1.0)
    sub = PowerRun(sut, scn, qsl=qsl, sample_hz=sample_hz, seed=SEED,
                   fault_plan=fault_plan).run()
    sim = sut.sim
    server = sub.outcome.server
    dur_s = sub.outcome.result.duration_s
    fleet_j = sub.per_domain_energy_j["pdu"]
    member_sum_j = sum(v for k, v in sub.per_domain_energy_j.items()
                       if k.endswith("/wall"))
    # R11 in metric form: the pdu register is the sum of the measured
    # replica feeds, exactly
    if abs(fleet_j - member_sum_j) > 1e-6 * max(fleet_j, 1.0):
        raise RuntimeError(
            f"{sut.name}: pdu {fleet_j} != sum of replica walls "
            f"{member_sum_j} — R11 broken")
    exact_j = sum(sim.replica_energy_j(dur_s))
    if abs(exact_j - fleet_j) > 0.02 * max(fleet_j, 1.0):
        raise RuntimeError(
            f"{sut.name}: exact replica ledger {exact_j} J vs measured "
            f"pdu {fleet_j} J drifted beyond sampling tolerance")
    tokens = sim.total_tokens()
    ledger = sim.energy_ledger_j(dur_s)
    controller = sim.controller
    return {
        "tokens_per_s": tokens / max(dur_s, 1e-9),
        "tok_per_j": tokens / max(fleet_j, 1e-12),
        "tail_attainment": server.tail_attainment,
        "avg_w": sub.summary.avg_watts,
        "provisioned_w_avg": sim.provisioned_w_avg(dur_s),
        "fleet_j": fleet_j,
        "idle_j": ledger["idle_j"],
        "cold_start_j": ledger["cold_start_j"],
        "cold_starts": sim.cold_starts,
        "scale_events": (controller.scale_events
                         if controller is not None else 0),
        "n_crashed": sim.n_crashed,
        "compliance_passed": float(sub.passed),
        "_peak_replica_w": max(max(r.trace.watts)
                               for r in sim.replicas),
        "_sub": sub,
    }


def _points(smoke: bool) -> dict:
    from repro.faults import FaultPlan, ReplicaCrash
    from repro.fleet import (CarbonAware, CarbonTrace, FleetController,
                             FleetSUT, TargetUtilization)

    t_tok_s = _measure_t_tok(smoke)
    unit_s = T_REQ_TOKS * t_tok_s
    rate = 1.0 / t_tok_s
    trace = _trace(smoke, unit_s)

    def controller_factory(slots_per_replica=SLOTS):
        return lambda: FleetController(
            TargetUtilization(target=TARGET_UTIL,
                              slots_per_replica=slots_per_replica),
            min_replicas=1, max_replicas=N_REPLICAS,
            cooldown_down_s=COOLDOWN_DOWN_UNITS * unit_s,
            down_ticks=DOWN_TICKS)

    def fleet(name, **kw):
        kw.setdefault("control_interval_s", CONTROL_UNITS * unit_s)
        kw.setdefault("default_out_tokens", OUT_TOKENS)
        return FleetSUT(_specs(rate, t_tok_s), name=name, **kw)

    crash_plan = FaultPlan([ReplicaCrash(
        replica=0, at_s=CRASH_AT_UNITS * unit_s)])
    carbon = CarbonTrace(period_s=HORIZON_UNITS * unit_s)
    configs = {
        "static_min": lambda: (fleet("fleet-static-min",
                                     initial_warm=2), None),
        "static_max": lambda: (fleet("fleet-static-max",
                                     initial_warm=N_REPLICAS), None),
        "autoscaled": lambda: (fleet(
            "fleet-autoscaled", initial_warm=1,
            make_controller=controller_factory()), None),
        "autoscaled_capped": lambda: (fleet(
            "fleet-autoscaled-capped", initial_warm=1,
            make_controller=controller_factory(),
            cap_w=CAP_W), None),
        "autoscaled_crash": lambda: (fleet(
            "fleet-autoscaled-crash", initial_warm=1,
            make_controller=controller_factory()), crash_plan),
        "hetero_carbon": lambda: (FleetSUT(
            _hetero_specs(rate, t_tok_s), name="fleet-hetero-carbon",
            initial_warm=1,
            make_controller=controller_factory(),
            make_router=lambda: CarbonAware(carbon=carbon),
            control_interval_s=CONTROL_UNITS * unit_s,
            default_out_tokens=OUT_TOKENS), None),
    }

    out: dict = {"calibration": {
        "tokens_per_s": rate, "t_tok_ms": t_tok_s * 1e3,
        "unit_ms": unit_s * 1e3,
        "n_arrivals": trace.n_arrivals,
        "horizon_s": trace.horizon_s}}
    for name, make in configs.items():
        sut, plan = make()
        row = _run_config(sut, trace, unit_s, t_tok_s, fault_plan=plan)
        sub = row.pop("_sub")
        peak_replica_w = row.pop("_peak_replica_w")
        if name == "autoscaled_capped":
            row["cap_w"] = CAP_W
            row["peak_replica_w"] = peak_replica_w
            if peak_replica_w > CAP_W + 1e-9:
                raise RuntimeError(
                    f"capped replica drew {peak_replica_w:.1f} W over "
                    f"the {CAP_W:.0f} W cap")
        if name == "hetero_carbon":
            times_s, watts = sub.power_samples()
            step_j = watts[:-1] * np.diff(times_s)
            row["emitted_gco2"] = carbon.emitted_gco2(
                step_j, times_s[:-1])
            row["gco2_per_kwh_avg"] = float(np.mean(
                carbon.intensity_gco2_per_kwh(times_s)))
        out[name] = row

    # the acceptance bar, gated as autoscaled.speedup: the autoscaled
    # fleet must beat always-warm max-N provisioning on J/token at
    # equal-or-better TTFT tail attainment over the same day
    auto, stat = out["autoscaled"], out["static_max"]
    speedup = auto["tok_per_j"] / stat["tok_per_j"]
    out["autoscaled"]["speedup"] = speedup
    if speedup <= 1.0:
        raise RuntimeError(
            f"autoscaled fleet J/token no better than static max-N "
            f"({auto['tok_per_j']:.4f} vs {stat['tok_per_j']:.4f} "
            f"tok/J)")
    if auto["tail_attainment"] < stat["tail_attainment"] - 1e-9:
        raise RuntimeError(
            f"autoscaled fleet lost tail attainment vs static max-N "
            f"({auto['tail_attainment']:.4f} < "
            f"{stat['tail_attainment']:.4f})")
    return out


def metrics(smoke: bool = False) -> dict:
    """Fleet Pareto sweep keyed for trend artifacts and the perf
    gate."""
    return _points(smoke)


def csv(smoke: bool = False) -> list[str]:
    points = _points(smoke)
    rows = []
    cal = points.pop("calibration")
    rows.append(f"fleet_calibration,{cal['tokens_per_s']:.1f},"
                f"t_tok={cal['t_tok_ms']:.2f}ms;"
                f"arrivals={cal['n_arrivals']};"
                f"horizon={cal['horizon_s']:.1f}s")
    for name, p in points.items():
        derived = (f"{p['tokens_per_s']:.1f}toks/s;"
                   f"{p['tok_per_j']:.4f}tok/J;"
                   f"attain={p['tail_attainment']:.3f};"
                   f"prov={p['provisioned_w_avg']:.0f}W;"
                   f"idle={p['idle_j']:.0f}J;"
                   f"cold={p['cold_start_j']:.0f}J"
                   f"({p['cold_starts']}starts)")
        if "speedup" in p:
            derived += f";speedup={p['speedup']:.2f}x"
        if "peak_replica_w" in p:
            derived += (f";cap={p['cap_w']:.0f}W;"
                        f"peak={p['peak_replica_w']:.0f}W")
        if "emitted_gco2" in p:
            derived += f";co2={p['emitted_gco2']:.1f}g"
        if p["n_crashed"]:
            derived += f";crashed={p['n_crashed']}"
        rows.append(f"fleet_{name},{p['tok_per_j']:.4f},{derived}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for row in csv(smoke=args.smoke):
        print(row)
