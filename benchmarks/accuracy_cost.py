"""Fig. 7: distribution of the efficiency drop when raising the
accuracy target 99% -> 99.9% (old fp16 deployments vs new fp8 ones)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, load_cell
from benchmarks.quantization_efficiency import (TARGET_999_NEW,
                                                TARGET_999_OLD, _eff)
from repro.configs import ASSIGNED_ARCHS


def run() -> list[dict]:
    rows = []
    for arch in ASSIGNED_ARCHS:
        rec = load_cell(arch, "prefill_32k", "pod")
        if rec is None:
            continue
        base = _eff(rec, "int8")
        for label, prec in (("fp16(old)", TARGET_999_OLD),
                            ("fp8(new)", TARGET_999_NEW)):
            rows.append({
                "arch": arch, "deployment": label,
                "delta_pct": 100.0 * (_eff(rec, prec) / base - 1.0),
            })
    return rows


def summary() -> dict:
    rows = run()
    old = [r["delta_pct"] for r in rows if r["deployment"] == "fp16(old)"]
    new = [r["delta_pct"] for r in rows if r["deployment"] == "fp8(new)"]
    out = {}
    if old:
        out["mean_drop_fp16_pct"] = float(np.mean(old))
    if new:
        out["mean_drop_fp8_pct"] = float(np.mean(new))
    return out


def csv() -> list[str]:
    out = [csv_row(f"fig7_acc_cost[{r['arch']}|{r['deployment']}]", 0.0,
                   f"delta_pct={r['delta_pct']:.2f}") for r in run()]
    s = summary()
    if s:
        out.append(csv_row("fig7_acc_cost[mean]", 0.0,
                           ";".join(f"{k}={v:.2f}" for k, v in s.items())))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
    print(summary())
