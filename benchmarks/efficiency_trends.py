"""Figs. 1 & 4: performance and normalized-efficiency trends across
benchmark versions — versions here are this repo's own optimization
history (baseline -> perf iterations), per hillclimbed workload, plus
the tiny/edge quantization step, mirroring the per-category trends."""
from __future__ import annotations

from benchmarks.common import all_cells, csv_row
from benchmarks.sw_hw_optimizations import PERF_TAGS, _submission
from repro.core.efficiency import normalized_trend


def run() -> dict[str, list]:
    subs = []
    for i, tag in enumerate(PERF_TAGS):
        for rec in all_cells(tag):
            if rec["mesh"] != "pod":
                continue
            subs.append(_submission(rec, "datacenter-v5e", f"v{i}",
                                    software_id=tag or "base"))
    # keep only workloads with >1 version (the hillclimbed cells)
    trend = normalized_trend(subs)
    return {wl: pts for wl, pts in trend.items() if len(pts) > 1}


def csv() -> list[str]:
    out = []
    for wl, pts in run().items():
        series = ";".join(f"{v}={x:.3f}" for v, x in pts)
        out.append(csv_row(f"fig4_trend[{wl}]", 0.0, series))
    return out


if __name__ == "__main__":
    for wl, pts in run().items():
        print(wl, pts)
