"""Speculative-decoding k-sweep: tokens/s, tokens/J, and the
draft-vs-target energy split across k ∈ {0, 2, 4, 8}.

The ML.ENERGY benchmark (arXiv:2505.06371) ranks speculative decoding
among the highest-leverage LLM inference energy optimizations — *when
the draft agrees with the target*.  This sweep quantifies both sides
of that trade on the serving stack:

- the **high-acceptance pair**: the draft is the target's first
  ``DRAFT_LAYERS`` blocks (LayerSkip-style self-draft, shared
  embeddings/head).  Random weights can't provide the distilled draft
  a real deployment would train, so the smoke target's upper layers
  are damped
  (``damp_upper_layers``) to *construct* the high-agreement regime —
  the target keeps its full depth and per-token cost, and the measured
  acceptance rate is reported alongside every row;
- the **low-acceptance row** (``spec_random_draft``): an independently
  initialized draft that almost never agrees — drafting then *costs*
  energy (every proposed token burns draft FLOPs the verify throws
  away), which is the regime the README's "when drafting costs energy"
  note documents.

Every point runs the same backlogged queue-form Server scenario
through ``PowerRun``; tok/J integrates the Director trace.  The energy
split is analytic: draft/target forward counts from the engine's
``spec_stats`` weighted by each model's parameter count.
"""
from __future__ import annotations

import numpy as np

SLOTS = 4
PROMPT_LEN = 8
MAX_LEN = 128
# the smoke target is deepened to 8 layers so the draft/target cost
# ratio (1 of 8 layers ≈ 0.2x with the shared embed/head) resembles a
# real deployment's much-smaller draft; the reduced config's 4 layers
# would make drafting nearly half as expensive as verifying
TARGET_LAYERS = 8
# uniform 80-decode-token budgets: long enough that decode (not
# prefill/admission) dominates each run, and 80 divides both the plain
# engine's 4-step chunks and the k=4 verify rounds (5 tokens), so
# neither engine pays budget/chunk misalignment waste — the sweep
# isolates the decode path itself (raggedness is covered by the
# parity tests)
MIX = (81, 81, 81, 81)
# saturating offered load: the whole queue arrives within a few ms so
# every point runs backlogged (at 200 qps the faster engines would
# idle waiting for arrivals and the sweep would measure the load, not
# the decode path)
QPS = 2000.0
K_SWEEP = (0, 2, 4, 8)
DRAFT_LAYERS = 1
DAMP = 0.001                  # upper-layer damping of the smoke target


def _make_request(cfg, rid, arrival_s):
    import jax

    from repro.serving import Request

    key = jax.random.PRNGKey(13)
    return Request(
        rid=rid,
        prompt=np.asarray(jax.random.randint(
            jax.random.fold_in(key, rid), (PROMPT_LEN,), 0,
            cfg.vocab_size)),
        max_new_tokens=MIX[rid % len(MIX)],
        arrival_s=float(arrival_s),
    )


N_REPS = 4


def _prepare_point(name, engine, cfg, draft_cfg, n_queries):
    """Warm an engine and return its measurement closure."""
    from repro.harness import ContinuousBatchingSUT, PowerRun, Server

    def make_request(i, s, a):
        from repro.core.loadgen import qid_of

        return _make_request(cfg, qid_of(s, i), a)

    # warmup/compile outside the measurement: a full slot-count batch
    # exercises prefill, chunks and refills before the measured runs
    engine.serve([_make_request(cfg, 10 ** 6 + j, 0.0)
                  for j in range(SLOTS + 1)], honor_arrivals=False)
    sut = ContinuousBatchingSUT(engine, cfg, name=f"spec-{name}",
                                make_request=make_request,
                                draft=draft_cfg)
    scenario = Server(target_qps=QPS, latency_slo_s=30.0,
                      min_duration_s=0.0, min_queries=n_queries,
                      mode="queue")

    def run_once():
        # 1 kHz on every meter-stack channel resolves the sub-second
        # measured window
        r = PowerRun(sut, scenario, seed=0, sample_hz=1000.0).run()
        # snapshot this repetition's engine accounting so the stats
        # reported for a point come from the same rep as its metrics
        r.spec_stats = dict(engine.spec_stats)
        return r

    return run_once


def _finish_point(r, engine, cfg, draft_cfg):
    m = r.outcome.server
    point = {
        "tokens_per_s": m.tokens_per_s,
        "tok_per_j": m.total_tokens / max(r.summary.energy_j, 1e-12),
        "us_per_tok": (r.outcome.result.duration_s
                       / max(1, m.total_tokens) * 1e6),
    }
    # the snapshot taken by run_once: the best rep's own accounting
    s = getattr(r, "spec_stats", engine.spec_stats)
    if engine.speculative:
        d_fwd = s["draft_fwd"] + s["draft_prefill_tokens"]
        t_fwd = (s["rounds"] * (engine.spec_k + 1)
                 + s["target_prefill_tokens"])
        d_cost = d_fwd * draft_cfg.param_count()
        t_cost = t_fwd * cfg.param_count()
        point["acceptance"] = s["accepted"] / max(1, s["proposed"])
        point["draft_energy_share"] = d_cost / max(d_cost + t_cost, 1e-12)
    return point


def _measure_points(setups):
    """Interleaved best-of-N_REPS per k point (the k-sweep speedups
    compare these sub-second numbers; see benchmarks.common)."""
    from benchmarks.common import interleaved_best_of

    best = interleaved_best_of(
        {name: run_once for name, (run_once, _, _, _) in setups.items()},
        n_reps=N_REPS)
    return {name: _finish_point(best[name], engine, cfg, draft_cfg)
            for name, (_, engine, cfg, draft_cfg) in setups.items()}


def _build(smoke: bool):
    import dataclasses

    import jax

    from repro.configs import get_config, reduce_config
    from repro.models import build_model
    from repro.models.param import init_params
    from repro.serving import damp_upper_layers, truncate_draft

    cfg = dataclasses.replace(reduce_config(get_config("qwen3-1.7b")),
                              n_layers=TARGET_LAYERS)
    model = build_model(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    # construct the high-acceptance regime (see module docstring): the
    # target keeps its full depth/cost, but its upper layers contribute
    # little, so the truncated self-draft agrees almost always
    params = damp_upper_layers(params, DRAFT_LAYERS, DAMP)
    dmodel, dparams = truncate_draft(model, params, DRAFT_LAYERS)
    return cfg, model, params, dmodel, dparams


def _points(smoke: bool) -> dict:
    import dataclasses

    import jax

    from repro.models import build_model
    from repro.models.param import init_params
    from repro.serving import ContinuousBatchingEngine

    cfg, model, params, dmodel, dparams = _build(smoke)
    n = 12 if smoke else 24
    setups: dict = {}
    for k in K_SWEEP:
        spec_kw = ({} if k == 0 else
                   dict(draft_model=dmodel, draft_params=dparams,
                        spec_k=k))
        eng = ContinuousBatchingEngine(
            model, params, max_len=MAX_LEN, n_slots=SLOTS,
            # plain decode: 4 tokens/chunk; speculative: 4 rounds/chunk
            # (up to 4*(k+1) tokens) — one host sync per chunk either
            # way, and the chunk loops exit early once every slot's
            # budget is spent
            chunk_steps=4, **spec_kw)
        draft_cfg = dmodel.cfg if k else None
        setups[f"k{k}"] = (_prepare_point(f"k{k}", eng, cfg, draft_cfg,
                                          n), eng, cfg, draft_cfg)

    # the cautionary point: an independent random draft (same shape as
    # the self-draft) that the target almost never agrees with
    rcfg = dataclasses.replace(cfg, n_layers=DRAFT_LAYERS,
                               name=f"{cfg.name}-random-draft")
    rmodel = build_model(rcfg)
    rparams = init_params(rmodel.param_defs(), jax.random.PRNGKey(99))
    eng = ContinuousBatchingEngine(
        model, params, max_len=MAX_LEN, n_slots=SLOTS, chunk_steps=4,
        draft_model=rmodel, draft_params=rparams, spec_k=4)
    setups["random_draft_k4"] = (
        _prepare_point("random-k4", eng, cfg, rcfg, n), eng, cfg, rcfg)

    points = _measure_points(setups)
    base = points["k0"]["tokens_per_s"]
    for name in points:
        if name != "k0":
            points[name]["speedup"] = (points[name]["tokens_per_s"]
                                       / max(base, 1e-12))
    return points


def metrics(smoke: bool = False) -> dict:
    """k-sweep numbers keyed for trend artifacts and the perf gate."""
    return _points(smoke)


def csv(smoke: bool = False) -> list[str]:
    points = _points(smoke)
    rows = []
    for name, p in points.items():
        derived = (f"{p['tokens_per_s']:.1f}toks/s;"
                   f"{p['tok_per_j']:.3f}tok/J")
        if "acceptance" in p:
            derived += (f";acc={p['acceptance']:.2f};"
                        f"draft_share={p['draft_energy_share']:.2f}")
        if "speedup" in p:
            derived += f";{p['speedup']:.2f}x"
        rows.append(f"spec_{name},{p['us_per_tok']:.1f},{derived}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for row in csv(smoke=args.smoke):
        print(row)
