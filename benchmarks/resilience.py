"""Resilience benchmark: efficiency + validity under injected faults.

Sweeps the three fault families of ``repro.faults`` — meter sample
dropout, replica crash, queue-overload burst — at increasing fault
rates, and for each level runs the same modeled serving fleet twice:

- **hardened**: every graceful-degradation path enabled (meter
  re-measure retries, crash re-dispatch onto survivors, admission-
  control load shedding, per-request deadlines, run-level retry), and
- **naive**: the same faults with every mitigation disabled.

Reported per level: ``goodput_per_j`` (deadline-met queries per Joule
of fleet boundary energy), ``slo_attainment`` (deadline-met fraction
of offered load), and run validity (did the compliance review accept
the run — a naive run may also die outright, e.g. a crash with no
re-dispatch path, which counts as invalid).  The whole benchmark is
modeled (pure numpy service/queueing model + the virtual meter stack,
fixed seeds), so the numbers are deterministic across machines and the
CI perf gate compares ``goodput_per_j`` raw against the committed
baseline.

    PYTHONPATH=src python -m benchmarks.resilience --smoke
"""
from __future__ import annotations

import argparse
from types import SimpleNamespace

import numpy as np

SEED = 13
TARGET_QPS = 4.0
SLO_S = 5.0
SERVICE_QPS = 8.0            # per-replica modeled service rate
WINDOW_S = 61.0
N_REPLICAS = 2

# fault levels, mildest first (l1 is the smoke + gate level)
DROPOUT_S = (8.0, 20.0, 40.0)          # seconds of lost wall samples
CRASH_AT_S = (50.0, 35.0, 20.0)        # earlier crash = more lost work
BURST_QPS = (12.0, 30.0, 60.0)         # 10 s overload burst rate


def _const(w):
    return lambda t, _w=float(w): np.full_like(np.asarray(t, float), _w)


def _sysdesc():
    from repro.core.compliance import SystemDescription

    return SystemDescription(scale="edge", max_system_watts=60,
                             idle_system_watts=8)


def _queue_serve(service_qps: float):
    """Modeled single-server replica: FIFO queue, deterministic
    service time — queueing delay (and thus deadline misses) emerges
    under overload instead of being scripted."""
    from repro.core.loadgen import qid_of

    def serve(arrivals):
        service = 1.0 / service_qps
        free = 0.0
        out = []
        for j, (s, a) in enumerate(arrivals):
            start = max(float(a), free)
            done = start + service
            free = done
            out.append(SimpleNamespace(
                rid=qid_of(s, j), arrival_s=float(a),
                first_token_s=start + 0.3 * service, done_s=done,
                output=[0] * 8, energy_j=None))
        return out

    return serve


def _replica(i: int):
    from repro.harness import CallableSUT
    from repro.power import PSUModel, PowerDomain

    psu = PSUModel(rated_watts=60.0, efficiency=0.9)
    rails = [PowerDomain("accelerator", _const(9.0 + i)),
             PowerDomain("host", _const(5.0))]
    wall = PowerDomain("wall",
                       psu.wall_source([r.source for r in rails]),
                       boundary=True)
    return CallableSUT(name=f"rep{i}", serve_queue=_queue_serve(
                           SERVICE_QPS),
                       psu=psu, domains_factory=lambda o: rails + [wall],
                       sysdesc=_sysdesc())


def _solo_sut():
    """Single-system SUT whose wall IS the boundary channel — the
    meter-dropout mode needs the R12 coverage invariant to bite."""
    from repro.harness import CallableSUT
    from repro.power import PSUModel, PowerDomain

    psu = PSUModel(rated_watts=60.0, efficiency=0.9)
    rails = [PowerDomain("accelerator", _const(9.0)),
             PowerDomain("host", _const(5.0))]
    wall = PowerDomain("wall",
                       psu.wall_source([r.source for r in rails]),
                       boundary=True)
    return CallableSUT(name="solo", serve_queue=_queue_serve(
                           2 * SERVICE_QPS),
                       psu=psu, domains_factory=lambda o: rails + [wall],
                       sysdesc=_sysdesc())


def _run(faults, *, fleet: bool, hardened: bool) -> dict:
    from repro.core.loadgen import ShedPolicy
    from repro.faults import FaultPlan, RetryPolicy
    from repro.harness import PowerRun, ReplicatedSUT, Server

    plan = FaultPlan(faults, seed=SEED)
    if fleet:
        sut = ReplicatedSUT([_replica(i) for i in range(N_REPLICAS)],
                            name="fleet",
                            retry=RetryPolicy() if hardened else None)
    else:
        sut = _solo_sut()
    scenario = Server(
        target_qps=TARGET_QPS, latency_slo_s=SLO_S, mode="queue",
        min_duration_s=WINDOW_S, seed=SEED, deadline_s=SLO_S,
        shed=ShedPolicy(max_queue=32) if hardened else None)
    kwargs = {}
    if hardened:
        kwargs = dict(meter_retry=RetryPolicy(),
                      retry_policy=RetryPolicy(max_attempts=2))
    try:
        r = PowerRun(sut, scenario, seed=0, fault_plan=plan,
                     **kwargs).run()
    except (RuntimeError, ValueError) as e:
        # a naive run may die outright (crash with no re-dispatch
        # path); that is an invalid run, not a benchmark error
        return {"valid": 0.0, "goodput_per_j": 0.0,
                "slo_attainment": 0.0, "died": type(e).__name__}
    m = r.outcome.server
    goodput = m.result.n_queries / max(r.summary.energy_j, 1e-12)
    return {"valid": 1.0 if r.passed else 0.0,
            "goodput_per_j": goodput,
            "slo_attainment": m.slo_attainment,
            "n_shed": m.n_shed, "n_timeout": m.n_timeout,
            "energy_j": r.summary.energy_j}


def _mode_faults(mode: str, level: float):
    from repro.faults import MeterDropout, QueueOverload, ReplicaCrash

    if mode == "meter_dropout":
        return [MeterDropout("wall", 5.0, level)], False
    if mode == "replica_crash":
        return [ReplicaCrash(1, at_s=level)], True
    if mode == "overload":
        return [QueueOverload(at_s=20.0, duration_s=10.0, qps=level)], True
    raise ValueError(mode)


def metrics(smoke: bool = False) -> dict:
    """Nested metrics for the CI perf gate + nightly trend artifact.
    ``l1`` (the mildest level) is measured in both smoke and full
    mode, so the committed smoke baseline gates every run."""
    levels = {"meter_dropout": DROPOUT_S, "replica_crash": CRASH_AT_S,
              "overload": BURST_QPS}
    n_levels = 1 if smoke else len(DROPOUT_S)
    out: dict = {"baseline": _run([], fleet=True, hardened=True)}
    for mode, lv in levels.items():
        per_mode: dict = {}
        valid, naive_valid = [], []
        for k, level in enumerate(lv[:n_levels], start=1):
            faults, fleet = _mode_faults(mode, level)
            hard = _run(faults, fleet=fleet, hardened=True)
            naive = _run(faults, fleet=fleet, hardened=False)
            valid.append(hard["valid"])
            naive_valid.append(naive["valid"])
            per_mode[f"l{k}"] = dict(
                hard, fault_level=float(level),
                naive_valid=naive["valid"],
                naive_slo_attainment=naive["slo_attainment"])
        per_mode["valid_rate"] = float(np.mean(valid))
        per_mode["naive_valid_rate"] = float(np.mean(naive_valid))
        out[mode] = per_mode
    return out


def csv(smoke: bool = False) -> list[str]:
    m = metrics(smoke=smoke)
    rows = [f"resilience_baseline,0.0,"
            f"{m['baseline']['goodput_per_j']:.4f}q/J;"
            f"slo={m['baseline']['slo_attainment']:.3f}"]
    for mode in ("meter_dropout", "replica_crash", "overload"):
        for key, lev in sorted(m[mode].items()):
            if not key.startswith("l"):
                continue
            rows.append(
                f"resilience_{mode}_{key},0.0,"
                f"{lev['goodput_per_j']:.4f}q/J;"
                f"slo={lev['slo_attainment']:.3f};"
                f"valid={lev['valid']:.0f};"
                f"naive_valid={lev['naive_valid']:.0f}")
        rows.append(f"resilience_{mode}_validity,0.0,"
                    f"hardened={m[mode]['valid_rate']:.2f};"
                    f"naive={m[mode]['naive_valid_rate']:.2f}")
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="mildest fault level only (CI chaos stage)")
    args = ap.parse_args(argv)
    for row in csv(smoke=args.smoke):
        print(row)


if __name__ == "__main__":
    main()
