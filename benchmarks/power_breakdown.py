"""Per-domain power/energy breakdown across the µW->MW scale axis.

The paper's central table, with one *column per measurement boundary*:
each scale point runs through the one-call harness with its
scale-appropriate ``MeterStack`` and reports the measured watts/Joules
split across power domains —

- ``tiny``  — duty-cycled MCU on the pin-demarcated DC channel (µW);
- ``edge``  — single edge SoC: accelerator/dram/host rails under a
  SPEC-class wall analyzer (W);
- ``tp1``   — one datacenter chip behind node telemetry (kW class);
- ``tp4``   — 4-way tensor parallel: one accelerator channel *per
  shard* summed under one wall;
- ``r2``    — a 2-replica fleet: per-replica stacks (rails + wall)
  aggregated by a derived PDU boundary (§IV-C fallback).

All points use modeled workloads on the simulated loadgen clock, so
the sweep is fast and device-independent — the point is the metering
API, not engine throughput (``benchmarks/scale_sweep.py`` measures
that).  Every row's run must pass the compliance review, including
the cross-domain invariants (wall >= sum of rails, wall == rails/eta
within channel error, PDU == sum of wall feeds); a rejected review
emits an ERROR row and fails ``benchmarks/run.py --smoke``.

  PYTHONPATH=src python -m benchmarks.power_breakdown --smoke
"""
from __future__ import annotations

import types

from benchmarks.common import csv_row

QPS = {"edge": 20.0, "tp1": 40.0, "tp4": 160.0, "r2": 40.0}


def _tiny_point():
    from repro.core.loadgen import Clock
    from repro.harness import PowerRun, SingleStream, TinySUT

    sut = TinySUT(lambda: None, macs=500_000, sram_bytes=60_000,
                  period_s=0.25, name="tiny-kws-model")
    r = PowerRun(sut, SingleStream(min_duration_s=61.0, min_queries=64),
                 clock=Clock(), seed=0).run()
    return r


def _dc_sysdesc(meter, scale="datacenter"):
    from repro.core.compliance import SystemDescription
    from repro.harness.sut import _system_peak_watts

    telemetry = 0.01 if scale == "datacenter" else None
    return SystemDescription(
        scale=scale, n_chips=meter.n_chips,
        instrument=("node-telemetry" if scale == "datacenter"
                    else "virtual-wt310"),
        telemetry_accuracy=telemetry,
        max_system_watts=_system_peak_watts(meter),
        idle_system_watts=meter.system_watts(None))


def _issue_point(name, meter, qps, *, n_accel_channels=1, psu=None,
                 scale="datacenter"):
    """One synthetic serving point: constant-latency issue function,
    analytic rail domains at the measured throughput."""
    from repro.configs import get_config
    from repro.core.loadgen import Clock
    from repro.harness import (CallableSUT, PowerRun, SingleStream,
                               rail_domains, throughput_work)

    cfg = get_config("qwen3-1.7b")
    psu = psu or meter.psu()
    sut = CallableSUT(
        name=name, issue=lambda s: 1.0 / qps, psu=psu,
        domains_factory=lambda o: rail_domains(
            meter, throughput_work(cfg, o.result.qps),
            n_accel_channels=n_accel_channels, psu=psu),
        sysdesc=_dc_sysdesc(meter, scale))
    r = PowerRun(sut, SingleStream(min_duration_s=61.0, min_queries=64),
                 clock=Clock(), seed=0).run()
    return r


def _fleet_point(n_replicas=2):
    """Replica fleet: synthetic admission queues, per-replica stacks
    under one derived PDU boundary."""
    from repro.configs import get_config
    from repro.core.loadgen import qid_of
    from repro.core.power_model import SystemPowerModel
    from repro.harness import (CallableSUT, PowerRun, ReplicatedSUT,
                               Server, rail_domains, throughput_work)
    from repro.hw import DATACENTER_V5E

    cfg = get_config("qwen3-1.7b")
    qps = QPS["r2"]

    def make_replica(i):
        meter = SystemPowerModel(DATACENTER_V5E, 1)

        def serve(arrivals):
            return [types.SimpleNamespace(
                rid=qid_of(s, j), arrival_s=a,
                first_token_s=a + 0.01, done_s=a + 0.05,
                output=[1, 2, 3, 4], energy_j=None)
                for j, (s, a) in enumerate(arrivals)]

        return CallableSUT(
            name=f"breakdown-replica{i}", serve_queue=serve,
            psu=meter.psu(),
            # replicas see an equal share of the offered load
            domains_factory=lambda o: rail_domains(
                meter, throughput_work(cfg, qps / n_replicas)),
            sysdesc=_dc_sysdesc(meter))

    sut = ReplicatedSUT([make_replica(i) for i in range(n_replicas)],
                        name=f"breakdown-r{n_replicas}")
    r = PowerRun(sut, Server(target_qps=qps, latency_slo_s=1.0,
                             mode="queue", min_duration_s=61.0,
                             min_queries=64), seed=0).run()
    return r


def _row(point, r) -> str:
    if not r.passed:
        fails = ";".join(c.rule for c in r.report.failures())
        return f"power_breakdown_{point},0.0,ERROR:review-rejected({fails})"
    watts = r.per_domain_watts
    cols = ";".join(f"{k}={v:.4g}W" for k, v in sorted(watts.items()))
    sj = r.samples_per_joule
    return csv_row(
        f"power_breakdown_{point}", 0.0,
        f"{cols};total={r.summary.energy_j:.4g}J;"
        f"boundary={'+'.join(r.summary.boundary_nodes)};"
        f"samples_per_j={sj:.4g}")


def run(smoke: bool = False) -> dict:
    from repro.core.power_model import SystemPowerModel
    from repro.hw import DATACENTER_V5E, EDGE_SYSTEM
    from repro.power import GOLD_CURVE, PSUModel

    out = {"tiny": _tiny_point()}
    edge_meter = SystemPowerModel(EDGE_SYSTEM, 1)
    # the edge point documents a load-dependent PSU loss curve (80
    # PLUS-style sag) instead of the flat datacenter efficiency
    edge_psu = PSUModel(rated_watts=edge_meter.psu().rated_watts,
                        curve=GOLD_CURVE)
    out["edge"] = _issue_point("breakdown-edge", edge_meter,
                               QPS["edge"], psu=edge_psu, scale="edge")
    dc1 = SystemPowerModel(DATACENTER_V5E, 1)
    out["tp1"] = _issue_point("breakdown-tp1", dc1, QPS["tp1"])
    dc4 = SystemPowerModel(DATACENTER_V5E, 4)
    out["tp4"] = _issue_point("breakdown-tp4", dc4, QPS["tp4"],
                              n_accel_channels=4)
    out["r2"] = _fleet_point()
    return out


def csv(smoke: bool = False) -> list[str]:
    return [_row(point, r) for point, r in run(smoke).items()]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for row in csv(smoke=args.smoke):
        print(row)
