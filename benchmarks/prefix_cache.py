"""Energy per cached token: the paged KV cache's prefix-sharing sweep.

The serving stack's radix prefix cache lets requests that share a
prompt prefix reuse the prefilled KV pages of earlier requests — a hit
computes only its unique suffix.  This sweep measures what that is
worth in Joules on the queue-form Server scenario, at prefix-hit rates
0, 0.5 and 0.9 over a prompt mix where a long shared system prompt
(``SHARED_LEN`` tokens) dominates a short unique tail:

- **tok/J and tok/s** per hit rate (PowerRun-integrated Director
  trace; the CI perf gate tracks both, and the acceptance bar is
  tok/J at hit-rate 0.9 >= 1.3x hit-rate 0);
- **J saved per cached token**: (E(0) - E(h)) / cached_tokens(h) —
  the headline energy value of one prompt token served from cache;
- **admission capacity**: how many concurrent request contexts the
  page pool can hold at each mix (shared pages counted once), vs the
  contiguous layout's ``pool / pages-per-slot`` — the second win of
  paging: shared prefixes stop occupying one copy per slot;
- **page-allocator ops/s**: a host-side microbenchmark of the
  refcounting free-list allocator (alloc/ref/unref), gated as a
  calibration-floored raw metric so an accidentally quadratic
  allocator fails CI even though it never shows up in sub-second
  tok/s numbers.

Every point serves identical budgets (same decode tokens), so tok/J
ratios between hit rates isolate the prefill compute the cache
skipped.
"""
from __future__ import annotations

import time

import numpy as np

SLOTS = 4
PAGE_SIZE = 8
MAX_LEN = 128
SHARED_LEN = 112                # shared system-prompt tokens (14 pages)
SUFFIX_LEN = 8                  # unique per-request tail
PROMPT_LEN = SHARED_LEN + SUFFIX_LEN
NEW_TOKENS = 4                  # short decode: prefill-dominated mix
HIT_RATES = (0.0, 0.5, 0.9)
N_REPS = 4
QPS = 2000.0                    # backlogged: measure the engine, not
                                # the arrival schedule


def _prompt(cfg, rid: int, shared: bool) -> np.ndarray:
    """Deterministic prompts: a fixed shared prefix + per-rid tail, or
    a fully per-rid prompt of the same length."""
    rng = np.random.default_rng(10_000 + rid)
    tail = rng.integers(0, cfg.vocab_size, SUFFIX_LEN)
    if not shared:
        head = rng.integers(0, cfg.vocab_size, SHARED_LEN)
        return np.concatenate([head, tail]).astype(np.int64)
    head = np.random.default_rng(7).integers(0, cfg.vocab_size,
                                             SHARED_LEN)
    return np.concatenate([head, tail]).astype(np.int64)


def _make_request(cfg, rid: int, arrival_s: float, hit_rate: float):
    from repro.serving import Request

    # spread the shared-prefix requests through the stream so hits and
    # misses interleave at every rate (the first shared one still
    # misses and pays the intern)
    shared = (rid % 10) < round(hit_rate * 10)
    return Request(rid=rid, prompt=_prompt(cfg, rid, shared),
                   max_new_tokens=NEW_TOKENS, arrival_s=float(arrival_s))


def _prepare_point(name, engine, cfg, hit_rate, n_queries):
    from repro.core.loadgen import qid_of
    from repro.harness import ContinuousBatchingSUT, PowerRun, Server

    def make_request(i, s, a):
        return _make_request(cfg, qid_of(s, i), a, hit_rate)

    # warmup/compile outside the measurement: a miss, a hit (the
    # extend path), and a full decode chunk
    engine.serve([_make_request(cfg, 10 ** 6 + j, 0.0, 1.0)
                  for j in range(2)], honor_arrivals=False)
    sut = ContinuousBatchingSUT(engine, cfg, name=f"prefix-{name}",
                                make_request=make_request)
    scenario = Server(target_qps=QPS, latency_slo_s=30.0,
                      min_duration_s=0.0, min_queries=n_queries,
                      mode="queue")

    def run_once():
        r = PowerRun(sut, scenario, seed=0, sample_hz=1000.0).run()
        # snapshot this repetition's cache accounting alongside it
        r.prefix_stats = dict(engine.prefix_stats)
        r.peak_pages = engine.page_pool.peak_used
        return r

    return run_once


def _capacity(usable_pages: int, hit_rate: float) -> int:
    """Concurrent request contexts the pool can hold at this mix:
    shared pages are resident once, each context then needs only its
    unique pages.  (Contiguous layout equivalent: SLOTS contexts.)"""
    pages_per_ctx = -(-(PROMPT_LEN + NEW_TOKENS) // PAGE_SIZE)
    shared_pages = SHARED_LEN // PAGE_SIZE
    unique_pages = pages_per_ctx - shared_pages
    if hit_rate <= 0:
        return usable_pages // pages_per_ctx
    # one resident copy of the shared prefix; hits add unique pages,
    # the (1 - h) misses still carry full contexts
    per_ctx = hit_rate * unique_pages + (1 - hit_rate) * pages_per_ctx
    return int((usable_pages - shared_pages) // per_ctx)


def _alloc_ops_per_s() -> float:
    """Host microbenchmark: allocator ops/s over alloc/ref/unref
    cycles shaped like admission traffic (16-page contexts, one
    shared-14 ref bump, interleaved frees)."""
    from repro.serving import PagePool

    pool = PagePool(4097, PAGE_SIZE)
    shared = pool.alloc(14)
    t0 = time.perf_counter()
    live: list[list[int]] = []
    while pool.alloc_ops < 200_000:
        for p in shared:
            pool.ref(p)
        live.append(pool.alloc(2))
        if len(live) > 64:
            for p in live.pop(0):
                pool.unref(p)
            for p in shared:
                pool.unref(p)
    dt = time.perf_counter() - t0
    return pool.alloc_ops / max(dt, 1e-9)


def _points(smoke: bool) -> dict:
    import jax

    from benchmarks.common import interleaved_best_of
    from repro.configs import get_config, reduce_config
    from repro.models import build_model
    from repro.models.param import init_params
    from repro.serving import ContinuousBatchingEngine

    cfg = reduce_config(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    n = 10 if smoke else 20

    setups: dict = {}
    for h in HIT_RATES:
        name = f"hit{int(h * 100)}"
        eng = ContinuousBatchingEngine(
            model, params, max_len=MAX_LEN, n_slots=SLOTS,
            chunk_steps=4, kv_page_size=PAGE_SIZE, prefix_caching=True)
        setups[name] = (_prepare_point(name, eng, cfg, h, n), eng, h)

    best = interleaved_best_of(
        {name: run_once for name, (run_once, _, _) in setups.items()},
        n_reps=N_REPS)

    points: dict = {}
    for name, (_, eng, h) in setups.items():
        r = best[name]
        m = r.outcome.server
        usable = eng.page_pool.n_pages - 1
        points[name] = {
            "tokens_per_s": m.tokens_per_s,
            "tok_per_j": m.total_tokens / max(r.summary.energy_j, 1e-12),
            "us_per_tok": (r.outcome.result.duration_s
                           / max(1, m.total_tokens) * 1e6),
            "energy_j": r.summary.energy_j,
            "cached_tokens": r.prefix_stats["cached_tokens"],
            "hits": r.prefix_stats["hits"],
            "lookups": r.prefix_stats["lookups"],
            "peak_pages": r.peak_pages,
            "capacity_ctx": _capacity(usable, h),
        }
    # the headline: Joules one cached prompt token is worth, from the
    # widest spread (hit-rate 0 vs 0.9 at identical decode budgets)
    e0 = points["hit0"]["energy_j"]
    for name, p in points.items():
        if p["cached_tokens"]:
            p["j_saved_per_cached_token"] = ((e0 - p["energy_j"])
                                             / p["cached_tokens"])
    points["allocator"] = {"page_alloc_ops_per_s": _alloc_ops_per_s()}
    return points


def metrics(smoke: bool = False) -> dict:
    """Hit-rate sweep keyed for trend artifacts and the perf gate."""
    return _points(smoke)


def csv(smoke: bool = False) -> list[str]:
    points = _points(smoke)
    rows = []
    for name, p in points.items():
        if name == "allocator":
            rows.append(f"prefix_{name},0.0,"
                        f"{p['page_alloc_ops_per_s']:.0f}ops/s")
            continue
        derived = (f"{p['tokens_per_s']:.1f}toks/s;"
                   f"{p['tok_per_j']:.3f}tok/J;"
                   f"hits={p['hits']}/{p['lookups']};"
                   f"capacity={p['capacity_ctx']}ctx;"
                   f"peak={p['peak_pages']}pages")
        if "j_saved_per_cached_token" in p:
            derived += (f";{p['j_saved_per_cached_token'] * 1e3:.2f}"
                        f"mJ/cached_tok")
        rows.append(f"prefix_{name},{p['us_per_tok']:.1f},{derived}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for row in csv(smoke=args.smoke):
        print(row)
