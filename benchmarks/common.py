"""Shared helpers: dry-run result loading + energy derivation.

Benchmarks read the compiled dry-run artifacts (experiments/dryrun/*.json)
when present and fall back to analytic StepWork estimates otherwise, so
``python -m benchmarks.run`` works on a fresh checkout.
"""
from __future__ import annotations

import glob
import json
import os

from repro.core.power_model import StepWork, SystemPowerModel, roofline
from repro.hw import DATACENTER_V5E, SystemSpec

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cell(arch: str, shape: str, mesh: str = "pod",
              tag: str = "") -> dict | None:
    suffix = f"__{tag}" if tag else ""
    p = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}{suffix}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def all_cells(tag: str = "") -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        name = os.path.basename(p)[:-5]
        parts = name.split("__")
        want_tag = parts[3] if len(parts) > 3 else ""
        if want_tag != tag:
            continue
        with open(p) as f:
            out.append(json.load(f))
    return out


def work_from_cell(rec: dict, int8: bool = False) -> StepWork:
    """Per-chip StepWork from a dry-run record.  ``int8``: model the
    quantized deployment (half the matmul bytes, int8 MXU path)."""
    flops = rec["flops"]
    hbm = rec["hbm_bytes"]
    if int8:
        return StepWork(flops=flops, hbm_bytes=hbm / 2,
                        ici_bytes=rec["coll_bytes"] / 2, flops_int8=flops)
    return StepWork(flops=flops, hbm_bytes=hbm,
                    ici_bytes=rec["coll_bytes"])


def cell_energy(rec: dict, system: SystemSpec = DATACENTER_V5E,
                int8: bool = False) -> dict:
    """Seconds + Joules for one executed step of a dry-run cell."""
    n = rec["n_devices"]
    model = SystemPowerModel(system, n)
    work = work_from_cell(rec, int8)
    rt = roofline(work, system.chip)
    step_s = rt.step_s
    watts = model.system_watts(work, step_s)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "n_chips": n, "step_s": step_s, "watts": watts,
        "energy_j": watts * step_s, "bottleneck": rt.bottleneck,
        "compute_s": rt.compute_s, "memory_s": rt.memory_s,
        "collective_s": rt.collective_s,
    }


def samples_per_step(rec: dict) -> float:
    """One 'sample' = one sequence (train/prefill) or one token (decode)."""
    from repro.configs import SHAPES

    shape = SHAPES[rec["shape"]]
    return float(shape.global_batch)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"


def interleaved_best_of(run_fns: dict, n_reps: int = 4) -> dict:
    """Best-of-``n_reps`` PowerRun per point, repetitions interleaved
    round-robin across the points.

    Sub-second measured runs are scheduler-noise-dominated on shared
    boxes, and the noise is *temporally correlated* (slow phases last
    seconds).  Interleaving lets every point sample the same machine
    conditions, so best-rep *ratios* between points are honest; the
    fastest repetition per point is the least-perturbed one
    (hyperfine-min style).  The CI perf gate and the k-sweep speedups
    compare these numbers.

    ``run_fns``: {point_name: zero-arg closure returning a
    ``SubmissionResult``}; returns {point_name: best result}.
    """
    best: dict = {}
    for _ in range(n_reps):
        for name, run_once in run_fns.items():
            r = run_once()
            if name not in best or (r.outcome.server.tokens_per_s
                                    > best[name].outcome.server
                                    .tokens_per_s):
                best[name] = r
    return best
