"""Figs. 9-10: software- vs hardware-isolated efficiency improvements.

(a) Software (Fig. 9/10a): identical "hardware" (v5e constants, same
    mesh), successive software versions = our own perf iterations
    (dry-run tags base -> opt*); efficiency delta distribution.
(b) Hardware (Fig. 10b): constant software stack (the same compiled
    workload), successive chip generations v4 -> v5e -> v5p.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import all_cells, csv_row, load_cell, work_from_cell
from repro.core.efficiency import Submission, software_isolated_deltas
from repro.core.power_model import SystemPowerModel, roofline
from repro.hw import SYSTEMS

HW_GENS = ["datacenter-v4", "datacenter-v5e", "datacenter-v5p"]
PERF_TAGS = ["", "opt1", "opt2", "opt3"]       # dry-run variant tags


def _submission(rec, system_key: str, version: str,
                software_id: str) -> Submission:
    system = SYSTEMS[system_key]
    work = work_from_cell(rec)
    model = SystemPowerModel(system, rec["n_devices"])
    rt = roofline(work, system.chip)
    from repro.configs import SHAPES
    sps = SHAPES[rec["shape"]].global_batch / rt.step_s
    return Submission(
        version=version, workload=f"{rec['arch']}/{rec['shape']}",
        scale="datacenter", system_id=system_key, software_id=software_id,
        samples_per_second=sps,
        avg_watts=model.system_watts(work, rt.step_s))


def run_software() -> list[dict]:
    subs = []
    for i, tag in enumerate(PERF_TAGS):
        for rec in all_cells(tag):
            if rec["mesh"] != "pod":
                continue
            subs.append(_submission(rec, "datacenter-v5e", f"v{i}",
                                    software_id=tag or "base"))
    return software_isolated_deltas(subs)


def run_hardware() -> list[dict]:
    rows = []
    for arch, shape in (("yi-9b", "train_4k"), ("qwen3-1.7b", "prefill_32k")):
        rec = load_cell(arch, shape, "pod")
        if rec is None:
            continue
        effs = {}
        for gen in HW_GENS:
            s = _submission(rec, gen, gen, "fixed-stack")
            effs[gen] = s.samples_per_joule
        base = effs[HW_GENS[0]]
        rows.append({"workload": f"{arch}/{shape}",
                     **{g: effs[g] / base for g in HW_GENS}})
    return rows


def csv() -> list[str]:
    out = []
    sw = run_software()
    if sw:
        deltas = [d["delta_pct"] for d in sw]
        out.append(csv_row(
            "fig9_sw_isolated", 0.0,
            f"n={len(deltas)};median_pct={np.median(deltas):.2f};"
            f"frac_positive={np.mean(np.asarray(deltas) > 0):.2f}"))
    for r in run_hardware():
        out.append(csv_row(
            f"fig10b_hw_isolated[{r['workload']}]", 0.0,
            ";".join(f"{g.split('-')[1]}={r[g]:.3f}" for g in HW_GENS)))
    return out


if __name__ == "__main__":
    print("software-isolated deltas:", run_software())
    print("hardware-isolated:", run_hardware())
