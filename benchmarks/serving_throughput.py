"""Serving throughput: fixed-batch vs continuous batching under a
Poisson Server load with mixed ``max_new_tokens``.

Measures real CPU wall time of both engines on the same reduced config
and the same arrival schedule, then derives tokens/s and tokens/Joule
(analytic busy-watts x duration).  The continuous engine wins on two
axes this benchmark isolates: finished slots are refilled mid-flight
instead of blocking the batch on its longest request, and the decode
loop runs whole chunks on device (one host sync per ``chunk_steps``
tokens instead of per token).
"""
from __future__ import annotations

import time

import numpy as np

SLOTS = 4
PROMPT_LEN = 16
MAX_LEN = 64
MIX = (4, 24, 8, 16)          # mixed budgets: stragglers + short ones


def _requests(cfg, n, qps, seed=0):
    import jax
    from repro.core.loadgen import poisson_arrivals
    from repro.serving import Request

    arr = poisson_arrivals(qps, min_duration_s=0.0, seed=seed,
                           min_queries=n)[:n]
    key = jax.random.PRNGKey(7)
    return [Request(rid=i,
                    prompt=np.asarray(jax.random.randint(
                        jax.random.fold_in(key, i), (PROMPT_LEN,), 0,
                        cfg.vocab_size)),
                    max_new_tokens=MIX[i % len(MIX)],
                    arrival_s=float(a))
            for i, a in enumerate(arr)]


def _run_fixed(engine, requests):
    """Fixed-batch baseline: batches formed in arrival order; each
    batch starts once its last member has arrived and the previous
    batch finished (the whole batch then blocks on its longest
    request).  Returns (duration_s, total_tokens)."""
    t = 0.0
    tokens = 0
    for i in range(0, len(requests), engine.batch):
        group = requests[i:i + engine.batch]
        ready = max(r.arrival_s for r in group)
        t0 = time.perf_counter()
        engine.run_batch(group)
        dt = time.perf_counter() - t0
        t = max(t, ready) + dt
        tokens += sum(len(r.output) for r in group)
    return t, tokens


def _run_continuous(engine, requests):
    t0 = time.perf_counter()
    done = engine.serve(requests)
    dt = time.perf_counter() - t0
    return dt, sum(len(r.output) for r in done)


def csv(smoke: bool = False) -> list[str]:
    import jax

    from repro.configs import get_config, reduce_config
    from repro.core.power_model import StepWork, SystemPowerModel
    from repro.hw import EDGE_SYSTEM
    from repro.models import build_model
    from repro.models.param import init_params
    from repro.serving import ContinuousBatchingEngine, ServeEngine

    cfg = reduce_config(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    fixed = ServeEngine(model, params, max_len=MAX_LEN, batch_size=SLOTS)
    cont = ContinuousBatchingEngine(model, params, max_len=MAX_LEN,
                                    n_slots=SLOTS, chunk_steps=4)
    n = 12 if smoke else 24
    # saturating offered load: both engines run backlogged, the
    # comparison isolates scheduling + host-sync overhead
    qps = 200.0

    meter = SystemPowerModel(EDGE_SYSTEM, 1)
    busy_w = meter.system_watts(StepWork(
        flops=2.0 * cfg.param_count() * 100.0,
        hbm_bytes=2.0 * cfg.param_count() * 100.0 / 8))

    # warm both jit caches outside the timed region
    _run_fixed(fixed, _requests(cfg, SLOTS, qps, seed=99))
    _run_continuous(cont, _requests(cfg, SLOTS, qps, seed=98))

    rows = []
    results = {}
    for name, runner, eng in (("fixed", _run_fixed, fixed),
                              ("continuous", _run_continuous, cont)):
        reqs = _requests(cfg, n, qps)
        dur, tokens = runner(eng, reqs)
        tok_s = tokens / dur
        tok_j = tokens / (busy_w * dur)
        results[name] = tok_s
        rows.append(f"serving_{name}_qps{qps:.0f},"
                    f"{dur / tokens * 1e6:.1f},"
                    f"{tok_s:.1f}toks/s;{tok_j:.3f}tok/J")
    rows.append(f"serving_continuous_speedup,0.0,"
                f"{results['continuous'] / results['fixed']:.2f}x;"
                f"chunk_syncs={cont.host_syncs}")
    return rows


if __name__ == "__main__":
    for row in csv():
        print(row)
