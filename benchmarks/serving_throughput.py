"""Serving throughput: fixed-batch vs continuous batching under a
Poisson Server load with mixed ``max_new_tokens``.

Both engines run behind the ``repro.harness`` API: each is a SUT with
a ``serve_queue``, driven by the queue-form ``Server`` scenario through
``PowerRun`` — so the measured tokens/Joule comes from the Director's
integrated energy, not a hand-multiplied watts x duration.  Both SUTs
declare the same constant busy-watts power source, so the tok/J ratio
isolates scheduling + host-sync overhead, which the continuous engine
wins on two axes: finished slots are refilled mid-flight instead of
blocking the batch on its longest request, and the decode loop runs
whole chunks on device (one host sync per ``chunk_steps`` tokens
instead of per token).
"""
from __future__ import annotations

import time

import numpy as np

SLOTS = 4
PROMPT_LEN = 16
MAX_LEN = 64
MIX = (4, 24, 8, 16)          # mixed budgets: stragglers + short ones


def _make_request(cfg, i, arrival_s):
    import jax
    from repro.serving import Request

    key = jax.random.PRNGKey(7)
    return Request(rid=i,
                   prompt=np.asarray(jax.random.randint(
                       jax.random.fold_in(key, i), (PROMPT_LEN,), 0,
                       cfg.vocab_size)),
                   max_new_tokens=MIX[i % len(MIX)],
                   arrival_s=float(arrival_s))


def _fixed_serve_queue(engine, cfg):
    """Fixed-batch baseline behind the ``serve_queue`` contract:
    batches formed in arrival order; each batch starts once its last
    member has arrived and the previous batch finished (the whole
    batch then blocks on its longest request).  Stamps run on the
    modeled timeline so latency = done_s - arrival_s is honest."""

    def serve(arrivals):
        reqs = [_make_request(cfg, i, a)
                for i, (_, a) in enumerate(arrivals)]
        t = 0.0
        done = []
        for i in range(0, len(reqs), engine.batch):
            group = reqs[i:i + engine.batch]
            base = max(t, max(r.arrival_s for r in group))
            wall0 = time.perf_counter()
            engine.run_batch(
                group, now=lambda: base + (time.perf_counter() - wall0))
            t = base + (time.perf_counter() - wall0)
            done.extend(group)
        return done

    return serve


def _continuous_serve_queue(engine, cfg):
    def serve(arrivals):
        reqs = [_make_request(cfg, i, a)
                for i, (_, a) in enumerate(arrivals)]
        return engine.serve(reqs)

    return serve


def metrics(smoke: bool = False) -> dict:
    """Measured numbers keyed for the CI perf gate
    (``scripts/perf_gate.py``): tokens/s and tokens/J per engine plus
    the continuous-over-fixed speedup ratio."""
    import jax

    from repro.configs import get_config, reduce_config
    from repro.core.power_model import SystemPowerModel
    from repro.harness import CallableSUT, PowerRun, Server, throughput_watts
    from repro.hw import EDGE_SYSTEM
    from repro.models import build_model
    from repro.models.param import init_params
    from repro.serving import ContinuousBatchingEngine, ServeEngine

    cfg = reduce_config(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    fixed = ServeEngine(model, params, max_len=MAX_LEN, batch_size=SLOTS)
    cont = ContinuousBatchingEngine(model, params, max_len=MAX_LEN,
                                    n_slots=SLOTS, chunk_steps=4)
    n = 12 if smoke else 24
    # saturating offered load: both engines run backlogged, the
    # comparison isolates scheduling + host-sync overhead
    qps = 200.0

    meter = SystemPowerModel(EDGE_SYSTEM, 1)
    busy_w = throughput_watts(meter, cfg, 100.0)

    # warm both jit caches outside the timed region
    warm = [(None, 0.0)] * SLOTS
    _fixed_serve_queue(fixed, cfg)(warm)
    _continuous_serve_queue(cont, cfg)(warm)

    scenario = Server(target_qps=qps, latency_slo_s=10.0,
                      min_duration_s=0.0, min_queries=n, mode="queue")
    suts = {
        "fixed": CallableSUT(name="serving-fixed",
                             serve_queue=_fixed_serve_queue(fixed, cfg),
                             power=busy_w),
        "continuous": CallableSUT(
            name="serving-continuous",
            serve_queue=_continuous_serve_queue(cont, cfg),
            power=busy_w),
    }

    def run_once(sut):
        # 1 kHz sampling resolves each engine's sub-second duration
        # (applied to every channel of the SUT's meter stack)
        return PowerRun(sut, scenario, seed=0, sample_hz=1000.0).run()

    # interleaved best-of-4: keeps the speedup ratio honest under
    # temporally-correlated machine noise (the CI perf gate compares
    # these numbers)
    from functools import partial

    from benchmarks.common import interleaved_best_of

    best = interleaved_best_of(
        {name: partial(run_once, sut) for name, sut in suts.items()})

    out: dict = {"qps": qps}
    for name, r in best.items():
        m = r.outcome.server
        dur = r.outcome.result.duration_s
        out[name] = {
            "tokens_per_s": m.tokens_per_s,
            "tok_per_j": m.total_tokens / max(r.summary.energy_j, 1e-12),
            "us_per_tok": dur / m.total_tokens * 1e6,
        }
    out["speedup"] = (out["continuous"]["tokens_per_s"]
                      / max(out["fixed"]["tokens_per_s"], 1e-12))
    out["chunk_syncs"] = cont.host_syncs
    # gate the multi-channel sampling path itself on a representative
    # 4-channel rail stack (accelerator/dram/host + PSU-derived wall)
    from repro.core.compliance import SystemDescription
    from repro.harness import rail_domains, throughput_work
    from repro.power import build_stack

    doms = rail_domains(meter, throughput_work(cfg, 100.0))
    stack = build_stack(
        doms, SystemDescription(scale="edge"), seed=0,
        sample_hz=1000.0, psu=meter.psu())
    out["meter_samples_per_s"] = meter_overhead(stack)
    return out


def meter_overhead(stack, duration_s: float = 2.0,
                   n_reps: int = 4) -> float:
    """Metering throughput of the multi-channel sampling path:
    channel-samples produced per second of metering wall time
    (best-of-``n_reps``; pure numpy, so a single max is stable).  The
    CI perf gate tracks this so adding channels or de-vectorizing the
    analyzer error model can't silently slow the serving group."""
    best = 0.0
    for _ in range(n_reps):
        t0 = time.perf_counter()
        out = stack.measure(duration_s)
        dt = time.perf_counter() - t0
        n = sum(len(t) for t, _ in out.values())
        best = max(best, n / max(dt, 1e-9))
    return best


def csv(smoke: bool = False) -> list[str]:
    m = metrics(smoke=smoke)
    qps = m["qps"]
    rows = []
    for name in ("fixed", "continuous"):
        p = m[name]
        rows.append(f"serving_{name}_qps{qps:.0f},"
                    f"{p['us_per_tok']:.1f},"
                    f"{p['tokens_per_s']:.1f}toks/s;"
                    f"{p['tok_per_j']:.3f}tok/J")
    rows.append(f"serving_continuous_speedup,0.0,"
                f"{m['speedup']:.2f}x;"
                f"chunk_syncs={m['chunk_syncs']}")
    rows.append(f"serving_meter_overhead,0.0,"
                f"{m['meter_samples_per_s']:.0f}samples/s")
    return rows


if __name__ == "__main__":
    for row in csv():
        print(row)
