"""Serving throughput: fixed-batch vs continuous batching under a
Poisson Server load with mixed ``max_new_tokens``.

Both engines run behind the ``repro.harness`` API: each is a SUT with
a ``serve_queue``, driven by the queue-form ``Server`` scenario through
``PowerRun`` — so the measured tokens/Joule comes from the Director's
integrated energy, not a hand-multiplied watts x duration.  Both SUTs
declare the same constant busy-watts power source, so the tok/J ratio
isolates scheduling + host-sync overhead, which the continuous engine
wins on two axes: finished slots are refilled mid-flight instead of
blocking the batch on its longest request, and the decode loop runs
whole chunks on device (one host sync per ``chunk_steps`` tokens
instead of per token).
"""
from __future__ import annotations

import time

import numpy as np

SLOTS = 4
PROMPT_LEN = 16
MAX_LEN = 64
MIX = (4, 24, 8, 16)          # mixed budgets: stragglers + short ones


def _make_request(cfg, i, arrival_s):
    import jax
    from repro.serving import Request

    key = jax.random.PRNGKey(7)
    return Request(rid=i,
                   prompt=np.asarray(jax.random.randint(
                       jax.random.fold_in(key, i), (PROMPT_LEN,), 0,
                       cfg.vocab_size)),
                   max_new_tokens=MIX[i % len(MIX)],
                   arrival_s=float(arrival_s))


def _fixed_serve_queue(engine, cfg):
    """Fixed-batch baseline behind the ``serve_queue`` contract:
    batches formed in arrival order; each batch starts once its last
    member has arrived and the previous batch finished (the whole
    batch then blocks on its longest request).  Stamps run on the
    modeled timeline so latency = done_s - arrival_s is honest."""

    def serve(arrivals):
        reqs = [_make_request(cfg, i, a)
                for i, (_, a) in enumerate(arrivals)]
        t = 0.0
        done = []
        for i in range(0, len(reqs), engine.batch):
            group = reqs[i:i + engine.batch]
            base = max(t, max(r.arrival_s for r in group))
            wall0 = time.perf_counter()
            engine.run_batch(
                group, now=lambda: base + (time.perf_counter() - wall0))
            t = base + (time.perf_counter() - wall0)
            done.extend(group)
        return done

    return serve


def _continuous_serve_queue(engine, cfg):
    def serve(arrivals):
        reqs = [_make_request(cfg, i, a)
                for i, (_, a) in enumerate(arrivals)]
        return engine.serve(reqs)

    return serve


def csv(smoke: bool = False) -> list[str]:
    import jax

    from repro.configs import get_config, reduce_config
    from repro.core.analyzer import AnalyzerSpec, VirtualAnalyzer
    from repro.core.director import Director
    from repro.core.power_model import SystemPowerModel
    from repro.harness import CallableSUT, PowerRun, Server, throughput_watts
    from repro.hw import EDGE_SYSTEM
    from repro.models import build_model
    from repro.models.param import init_params
    from repro.serving import ContinuousBatchingEngine, ServeEngine

    cfg = reduce_config(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    fixed = ServeEngine(model, params, max_len=MAX_LEN, batch_size=SLOTS)
    cont = ContinuousBatchingEngine(model, params, max_len=MAX_LEN,
                                    n_slots=SLOTS, chunk_steps=4)
    n = 12 if smoke else 24
    # saturating offered load: both engines run backlogged, the
    # comparison isolates scheduling + host-sync overhead
    qps = 200.0

    meter = SystemPowerModel(EDGE_SYSTEM, 1)
    busy_w = throughput_watts(meter, cfg, 100.0)

    # warm both jit caches outside the timed region
    warm = [(None, 0.0)] * SLOTS
    _fixed_serve_queue(fixed, cfg)(warm)
    _continuous_serve_queue(cont, cfg)(warm)

    scenario = Server(target_qps=qps, latency_slo_s=10.0,
                      min_duration_s=0.0, min_queries=n, mode="queue")
    rows = []
    results = {}
    for name, serve in (("fixed", _fixed_serve_queue(fixed, cfg)),
                        ("continuous", _continuous_serve_queue(cont, cfg))):
        sut = CallableSUT(name=f"serving-{name}", serve_queue=serve,
                          power=busy_w)
        # runs last well under a second: sample at 1 kHz so the energy
        # window resolves each engine's actual duration
        director = Director(analyzer=VirtualAnalyzer(
            AnalyzerSpec(sample_hz=1000.0), seed=0), seed=0)
        r = PowerRun(sut, scenario, seed=0, director=director).run()
        m = r.outcome.server
        dur = r.outcome.result.duration_s
        tok_j = m.total_tokens / max(r.summary.energy_j, 1e-12)
        results[name] = m.tokens_per_s
        rows.append(f"serving_{name}_qps{qps:.0f},"
                    f"{dur / m.total_tokens * 1e6:.1f},"
                    f"{m.tokens_per_s:.1f}toks/s;{tok_j:.3f}tok/J")
    rows.append(f"serving_continuous_speedup,0.0,"
                f"{results['continuous'] / results['fixed']:.2f}x;"
                f"chunk_syncs={cont.host_syncs}")
    return rows


if __name__ == "__main__":
    for row in csv():
        print(row)
