"""Max sustainable QPS at a fixed TTFT/TPOT tail SLO, per joule.

The Server-scenario capacity question the SLO-aware serving stack
exists to answer: on a bimodal prompt mix (short interactive queries
+ long context-stuffing queries), how many queries per second can
each engine configuration sustain while the *interactive* class keeps
meeting its time-to-first-token SLO — and what does that capacity
cost in watts?  Four configurations share one geometry and one tight
page pool:

- **monolithic**  — paged KV, whole-prompt prefill at admission: a
  short arriving behind a long prompt waits the full prefill
  (head-of-line blocking), so attainment collapses as long-prompt
  traffic grows;
- **chunked**     — ``prefill_chunk_tokens`` splits every prefill
  into chunks interleaved with decode chunks: shorts slip in between
  a long's chunks and decoding slots never stall;
- **chunked_preempt** — chunked + ``Scheduler(preemption=True)``:
  deadline-slack admission ordering, and under page-pool pressure a
  low-priority long is parked (pages evicted, state host-side) so the
  short admits immediately; the long resumes bit-identically through
  the prefix-cache extend path;
- **disaggregated** — prefill and decode as separate fleets
  (``PrefillWorker`` x2 -> paged KV handoff -> decode engine), each
  behind its own ``PowerDomain`` stack, so the prefill-vs-decode
  energy split is *measured* per boundary channel, not modeled.

Every timing knob is calibrated to the measured warm monolithic
long-prompt prefill time ``t_long`` (the SLO is ``SLO_FRAC x
t_long``, the offered-QPS grid is ``GRID_x / t_long``), so the
collision geometry — which shorts land behind which longs — is
machine-speed invariant and the gate baselines transfer across
hosts.  Arrivals are Poisson at a fixed seed: deterministic given
the grid point.

Reported per configuration (group ``qps_at_slo_per_j`` in the perf
gate): ``tokens_per_s`` / ``tok_per_j`` at the shared mid grid point
(throughput + efficiency at equal offered load), ``qps_at_slo``
(``repro.core.efficiency.max_sustainable_qps`` over the grid at
``ATTAIN_BAR`` short-class attainment), ``qps_at_slo_per_j``
(capacity per watt at the sustaining point), the per-point
attainments, and for the preemptive config the gated ``speedup`` =
its sustainable QPS over monolithic's — the acceptance bar that
chunked+preempt strictly beats monolithic.  The disaggregated row
adds ``prefill_j`` / ``decode_j`` / ``prefill_energy_frac`` from the
two fleets' measured wall channels.
"""
from __future__ import annotations

import time

import numpy as np

LONG_LEN = 768                  # context-stuffing prompt (12 pages)
SHORT_LEN = 16                  # interactive prompt (1 page w/ budget)
MAX_LEN = 832                   # 13 pages per slot
PAGE_SIZE = 64
SLOTS = 8                       # slots are not the binding constraint
KV_PAGES = 27                   # two resident longs fill the pool: the
                                # third concurrent context must wait
                                # (monolithic/chunked) or preempt
CHUNK_STEPS = 2                 # decode tokens per fused chunk
PREFILL_CHUNK = 64              # chunked-prefill tokens per iteration
NEW_TOKENS = 8                  # decode budget (both classes)
LONG_PERIOD = 8                 # arrival pattern period ...
LONG_SLOTS = (0, 5)             # ... longs at these offsets (25 %,
                                # alternating parity so the disagg
                                # round-robin splits them evenly)
SLO_FRAC = 0.35                 # ttft_slo = SLO_FRAC * t_long
TPOT_FRAC = 0.5                 # tpot_slo = TPOT_FRAC * t_long (loose:
                                # the sweep discriminates on TTFT)
ATTAIN_BAR = 0.9                # short-class TTFT attainment bar
GRID_X = (0.4, 1.0, 2.0)        # offered qps = x / t_long (smoke)
GRID_X_FULL = (0.4, 0.8, 1.2, 1.6, 2.0, 2.4)
MID = 1                         # grid index for the fixed-load
                                # tokens_per_s / tok_per_j comparison
N_PREFILL_WORKERS = 2
SEED = 0                        # Poisson arrival schedule seed


def _is_long(i: int) -> bool:
    return i % LONG_PERIOD in LONG_SLOTS


def _prompt(cfg, i: int) -> np.ndarray:
    """Deterministic per-arrival-index prompts, unique content per
    request so the prefix cache in the preemptive config never
    cross-hits between requests (only park/resume reuses pages)."""
    n = LONG_LEN if _is_long(i) else SHORT_LEN
    rng = np.random.default_rng(20_000 + i)
    return rng.integers(0, cfg.vocab_size, n).astype(np.int64)


def _make_request(cfg, rid: int, i: int, arrival_s: float,
                  ttft_slo_s: float):
    """Shorts are the interactive class: priority 1 with a deadline at
    arrival + SLO (drives the scheduler's slack ordering); longs are
    best-effort priority 0 — the preemption victims."""
    from repro.serving import Request

    short = not _is_long(i)
    return Request(
        rid=rid, prompt=_prompt(cfg, i), max_new_tokens=NEW_TOKENS,
        arrival_s=float(arrival_s),
        priority=1 if short else 0,
        deadline_s=float(arrival_s) + ttft_slo_s if short else None)


def _warm(engine, cfg, *, chunked: bool, prefix: bool) -> None:
    """Compile every shape outside the measurement: long + short
    prefill (monolithic or chunked), a decode chunk, and for the
    prefix-caching config the intern + extend (resume) paths.

    The two opposite-order serves matter for the disaggregated
    engine: its round-robin worker assignment would otherwise leave
    one prefill worker having only ever compiled one prompt shape,
    and the first short on the other worker would pay a mid-
    measurement XLA compile that reads as an SLO miss."""
    from repro.serving import Request

    def req(j, n):
        rng = np.random.default_rng(5_000 + j)
        return Request(rid=10 ** 6 + j,
                       prompt=rng.integers(0, cfg.vocab_size, n),
                       max_new_tokens=NEW_TOKENS)

    engine.serve([req(0, LONG_LEN), req(1, SHORT_LEN)],
                 honor_arrivals=False)
    engine.serve([req(2, SHORT_LEN), req(3, LONG_LEN)],
                 honor_arrivals=False)
    if prefix:
        # re-offering the long compiles the full-prefix-hit extend;
        # the +k tails compile the park/resume shapes — a parked long
        # resumes with prompt' = prompt + output where a chunk-
        # aligned park leaves len(output) odd (first token + 2/chunk)
        # and 768 cached tokens, i.e. extend tails of 1/3/5/7 tokens
        # (the same shapes an evicted-then-rechunked resume reaches)
        long_p = np.asarray(req(0, LONG_LEN).prompt)
        extra = np.random.default_rng(5_999).integers(
            0, cfg.vocab_size, NEW_TOKENS)
        engine.serve([req(0, LONG_LEN)], honor_arrivals=False)
        engine.serve(
            [Request(rid=10 ** 6 + 10 + k,
                     prompt=np.concatenate([long_p, extra[:k]]),
                     max_new_tokens=NEW_TOKENS)
             for k in range(1, NEW_TOKENS, 2)],
            honor_arrivals=False)


def _measure_t_long(engine, cfg) -> float:
    """Warm monolithic long-prompt TTFT (seconds): the calibration
    unit every SLO and grid rate is expressed in."""
    from repro.serving import Request

    ts = []
    for j in range(3):
        rng = np.random.default_rng(6_000 + j)
        r = Request(rid=10 ** 6 + 100 + j,
                    prompt=rng.integers(0, cfg.vocab_size, LONG_LEN),
                    max_new_tokens=1)
        t0 = time.perf_counter()
        engine.serve([r], honor_arrivals=False)
        ts.append(time.perf_counter() - t0)
    return float(min(ts))


def _short_attainment(completed, ttft_slo_s: float) -> float:
    """Fraction of *short-class* requests whose TTFT met the SLO —
    the interactive-latency constraint capacity is maximised under
    (long prompts necessarily exceed a sub-prefill TTFT bound)."""
    ttfts = [r.first_token_s - r.arrival_s for r in completed
             if len(r.prompt) < LONG_LEN]
    if not ttfts:
        return float("nan")
    return float(np.mean([t <= ttft_slo_s for t in ttfts]))


def _run_grid(sut, grid_qps, ttft_slo_s, tpot_slo_s, n_queries):
    """One PowerRun per offered rate, ascending; returns
    ``[(qps, short_attainment, SubmissionResult, sched_stats)]``."""
    from repro.harness import PowerRun, Server

    points = []
    for qps in grid_qps:
        scenario = Server(target_qps=qps, latency_slo_s=30.0,
                          min_duration_s=0.0, min_queries=n_queries,
                          mode="queue", seed=SEED,
                          ttft_slo_s=ttft_slo_s, tpot_slo_s=tpot_slo_s)
        res = PowerRun(sut, scenario, seed=0, sample_hz=1000.0).run()
        attain = _short_attainment(sut.completed, ttft_slo_s)
        eng = sut.engine
        stats = dict(getattr(eng, "sched_stats", None)
                     or getattr(getattr(eng, "engine", None),
                                "sched_stats", None) or {})
        points.append((qps, attain, res, stats))
    return points


def _point_metrics(points, grid_qps, floor_qps):
    """Grid -> the group's leaves: fixed-load throughput/efficiency at
    the MID point, sustainable QPS, and capacity per watt at the
    highest sustaining point."""
    from repro.core.efficiency import (max_sustainable_qps,
                                       qps_at_slo_per_joule)

    msq = max_sustainable_qps([(q, a) for q, a, _, _ in points],
                              min_attainment=ATTAIN_BAR)
    # nothing sustained: floor at half the lowest grid rate so the
    # gated speedup ratios stay finite (reads as "below the grid")
    msq_eff = msq if msq > 0 else floor_qps
    at = next((p for p in reversed(points) if p[0] <= msq_eff), points[0])
    mid = points[min(MID, len(points) - 1)]
    m = mid[2].outcome.server
    out = {
        "tokens_per_s": m.tokens_per_s,
        "tok_per_j": m.total_tokens / max(mid[2].summary.energy_j,
                                          1e-12),
        "qps_at_slo": msq,
        "qps_at_slo_per_j": qps_at_slo_per_joule(
            msq_eff, at[2].summary.avg_watts),
    }
    for (q, a, _, _), x in zip(points, grid_qps):
        out[f"attain_x{int(round(x * 10))}"] = a
    return out, msq_eff


def _points(smoke: bool) -> dict:
    import jax

    from repro.configs import get_config, reduce_config
    from repro.core.loadgen import qid_of
    from repro.harness import (ContinuousBatchingSUT, DisaggregatedSUT)
    from repro.models import build_model
    from repro.models.param import init_params
    from repro.serving import (ContinuousBatchingEngine,
                               DisaggregatedEngine, PrefillWorker,
                               Scheduler)

    cfg = reduce_config(get_config("qwen3-1.7b"))
    model = build_model(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))
    n = 24 if smoke else 48
    grid_x = GRID_X if smoke else GRID_X_FULL

    def engine(**kw):
        return ContinuousBatchingEngine(
            model, params, max_len=MAX_LEN, n_slots=SLOTS,
            chunk_steps=CHUNK_STEPS, kv_page_size=PAGE_SIZE,
            kv_pages=KV_PAGES, **kw)

    # calibration: a dedicated monolithic engine measures t_long warm
    cal = engine()
    _warm(cal, cfg, chunked=False, prefix=False)
    t_long = _measure_t_long(cal, cfg)
    ttft_slo = SLO_FRAC * t_long
    tpot_slo = TPOT_FRAC * t_long
    grid_qps = [x / t_long for x in grid_x]
    floor_qps = grid_qps[0] / 2.0

    configs = {
        "monolithic": (engine(), False, False),
        "chunked": (engine(prefill_chunk_tokens=PREFILL_CHUNK),
                    True, False),
        "chunked_preempt": (engine(prefill_chunk_tokens=PREFILL_CHUNK,
                                   prefix_caching=True,
                                   scheduler=Scheduler(preemption=True)),
                            True, True),
    }

    points_out: dict = {"calibration": {
        "t_long_ms": t_long * 1e3, "ttft_slo_ms": ttft_slo * 1e3,
        "grid_qps": [round(q, 3) for q in grid_qps]}}
    msq_by_name: dict = {}
    for name, (eng, chunked, prefix) in configs.items():
        _warm(eng, cfg, chunked=chunked, prefix=prefix)

        def make_request(i, s, a, _slo=ttft_slo):
            return _make_request(cfg, qid_of(s, i), i, a, _slo)

        sut = ContinuousBatchingSUT(eng, cfg, name=f"slo-{name}",
                                    make_request=make_request)
        pts = _run_grid(sut, grid_qps, ttft_slo, tpot_slo, n)
        out, msq_eff = _point_metrics(pts, grid_x, floor_qps)
        msq_by_name[name] = msq_eff
        if name == "chunked_preempt":
            out["preemptions"] = sum(s.get("preemptions", 0)
                                     for _, _, _, s in pts)
            out["resumes"] = sum(s.get("resumes", 0)
                                 for _, _, _, s in pts)
        if chunked:
            dc = sum(s.get("decode_chunks", 0) for _, _, _, s in pts)
            il = sum(s.get("interleaved_chunks", 0)
                     for _, _, _, s in pts)
            out["interleave_ratio"] = il / max(1, dc)
        points_out[name] = out

    # disaggregated: prefill fleet -> paged handoff -> decode fleet,
    # separate meter stacks per fleet (measured energy split)
    dec = engine()
    workers = [PrefillWorker(dec.model, dec.params, page_size=PAGE_SIZE)
               for _ in range(N_PREFILL_WORKERS)]
    deng = DisaggregatedEngine(workers, dec)
    _warm(deng, cfg, chunked=False, prefix=False)

    def make_request_d(i, s, a, _slo=ttft_slo):
        return _make_request(cfg, qid_of(s, i), i, a, _slo)

    dsut = DisaggregatedSUT(deng, cfg, name="slo-disaggregated",
                            make_request=make_request_d)
    pts = _run_grid(dsut, grid_qps, ttft_slo, tpot_slo, n)
    out, msq_eff = _point_metrics(pts, grid_x, floor_qps)
    msq_by_name["disaggregated"] = msq_eff
    dom = pts[min(MID, len(pts) - 1)][2].per_domain_energy_j
    out["prefill_j"] = dom.get("prefill/wall", 0.0)
    out["decode_j"] = dom.get("decode/wall", 0.0)
    total = out["prefill_j"] + out["decode_j"]
    out["prefill_energy_frac"] = out["prefill_j"] / max(total, 1e-12)
    points_out["disaggregated"] = out

    # the acceptance bar, gated: preemptive chunked serving sustains
    # strictly more SLO-compliant QPS than monolithic admission
    points_out["chunked_preempt"]["speedup"] = (
        msq_by_name["chunked_preempt"] / msq_by_name["monolithic"])
    return points_out


def metrics(smoke: bool = False) -> dict:
    """QPS-at-SLO sweep keyed for trend artifacts and the perf gate."""
    return _points(smoke)


def csv(smoke: bool = False) -> list[str]:
    points = _points(smoke)
    rows = []
    cal = points.pop("calibration")
    rows.append(f"slo_calibration,{cal['t_long_ms']:.1f},"
                f"slo={cal['ttft_slo_ms']:.1f}ms;"
                f"grid={'/'.join(str(q) for q in cal['grid_qps'])}qps")
    for name, p in points.items():
        derived = (f"{p['tokens_per_s']:.1f}toks/s;"
                   f"{p['tok_per_j']:.3f}tok/J;"
                   f"msq={p['qps_at_slo']:.2f}qps;"
                   f"{p['qps_at_slo_per_j']:.4f}qps_at_slo/J")
        if "speedup" in p:
            derived += f";speedup={p['speedup']:.2f}x"
        if "preemptions" in p:
            derived += (f";preempt={p['preemptions']}"
                        f";resume={p['resumes']}")
        if "prefill_j" in p:
            derived += (f";prefill={p['prefill_j']:.2f}J"
                        f";decode={p['decode_j']:.2f}J"
                        f";prefill_frac={p['prefill_energy_frac']:.2f}")
        attains = ";".join(
            f"{k[7:]}={v:.2f}" for k, v in sorted(p.items())
            if k.startswith("attain_"))
        rows.append(f"slo_{name},{p['qps_at_slo']:.2f},"
                    f"{derived};{attains}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    for row in csv(smoke=args.smoke):
        print(row)
